"""Crash/corruption tests for §17 bulk ingest (§14 injection points,
§12.2 CRC rejection).

A bulk run may die at any of the three ingest injection points
(``ingest.lemmatize`` / ``ingest.spill`` / ``ingest.merge``) or find its
on-disk spill cache torn or bit-flipped.  The contract under test:

* a crash leaves only durable prefixes — ``resume=True`` revalidates by
  CRC, redoes exactly the invalid work, and the finished snapshot is
  **byte-identical** to an uncrashed run's;
* physical corruption (truncation, bit-flip) is *rejected*, never merged:
  either the resume path rebuilds the bad spill or the merge fails cleanly
  with ``StoreError`` and no snapshot is published.

No real sleeps anywhere — faults fire deterministically by arrival count.
"""

from __future__ import annotations

from pathlib import Path

import pytest

from repro.index.ingest import bulk_build
from repro.index.store import StoreError
from repro.search.resilience import FaultEvent, FaultInjector, ShardCrash

SW, FU = 8, 16
TEXTS = [
    f"doc {i} the who are you who walk to be or not to be w{i % 7:03d}"
    for i in range(12)
]
DPS = 4  # -> 3 chunks


def _tree_bytes(root: Path) -> dict[str, bytes]:
    return {
        str(p.relative_to(root)): p.read_bytes()
        for p in sorted(Path(root).rglob("*"))
        if p.is_file()
    }


def _build(out, injector=None, resume=False, **kw):
    return bulk_build(
        TEXTS, out_dir=out, sw_count=SW, fu_count=FU,
        docs_per_spill=DPS, injector=injector, resume=resume, **kw,
    )


def _assert_equals_uncrashed(out, tmp_path):
    ref = tmp_path / "uncrashed_ref"
    _build(ref)
    got, want = _tree_bytes(Path(out) / "snap_0"), _tree_bytes(ref / "snap_0")
    assert set(got) == set(want)
    diff = [k for k in sorted(got) if got[k] != want[k]]
    assert not diff, f"resumed snapshot differs from uncrashed: {diff}"


def test_crash_mid_spill_then_resume_is_byte_identical(tmp_path):
    out = tmp_path / "out"
    inj = FaultInjector([FaultEvent("ingest.spill", "crash", shard=1)])
    with pytest.raises(ShardCrash):
        _build(out, injector=inj)
    # the crash aborted before publish: no snapshot, but durable chunks
    assert not list(out.glob("snap_*"))
    assert (out / "ingest_run" / "chunk_0000" / "chunk.json").exists()
    stats = _build(out, resume=True)
    # every chunk survived phase L; spill 0 completed before the crash
    assert stats.chunks_reused == 3 and stats.spills_reused == 1
    _assert_equals_uncrashed(out, tmp_path)


def test_crash_mid_lemmatize_then_resume_is_byte_identical(tmp_path):
    out = tmp_path / "out"
    inj = FaultInjector([FaultEvent("ingest.lemmatize", "crash", shard=2)])
    with pytest.raises(ShardCrash):
        _build(out, injector=inj)
    stats = _build(out, resume=True)
    assert stats.chunks_reused == 2  # chunks 0,1 durable; chunk 2 redone
    _assert_equals_uncrashed(out, tmp_path)


def test_fresh_run_ignores_crashed_leftovers(tmp_path):
    """Without resume=True a partial run is discarded, never continued —
    the leftover could be from an incompatible invocation."""
    out = tmp_path / "out"
    inj = FaultInjector([FaultEvent("ingest.spill", "crash", shard=0)])
    with pytest.raises(ShardCrash):
        _build(out, injector=inj)
    stats = _build(out)  # resume NOT requested
    assert stats.chunks_reused == 0 and stats.spills_reused == 0
    _assert_equals_uncrashed(out, tmp_path)


def test_bitflip_spill_is_rejected_and_nothing_published(tmp_path):
    """A bit-flipped spill segment must fail the §12.2 CRC verify inside the
    merge — a clean StoreError, not silently-wrong postings — and the run
    must not publish a snapshot."""
    out = tmp_path / "out"
    inj = FaultInjector([FaultEvent("ingest.merge", "bitflip", shard=1)])
    with pytest.raises(StoreError):
        _build(out, injector=inj)
    assert inj.log and inj.log[0]["kind"] == "bitflip"
    assert not list(out.glob("snap_*"))
    # the corruption is recoverable: resume revalidates spills by CRC,
    # rebuilds the poisoned one and completes
    stats = _build(out, resume=True)
    assert stats.spills_reused == 2  # chunks 0,2 intact; chunk 1 rebuilt
    _assert_equals_uncrashed(out, tmp_path)


def test_truncated_spill_is_rebuilt_on_resume(tmp_path):
    """Torn write (power loss mid-spill): spills are unsynced caches, so a
    truncated blob must be caught by CRC validation and rebuilt."""
    out = tmp_path / "out"
    inj = FaultInjector([FaultEvent("ingest.merge", "crash", shard=0)])
    with pytest.raises(ShardCrash):
        _build(out, injector=inj)  # dies entering the merge: all spills on disk
    victim = out / "ingest_run" / "chunk_0001" / "seg_000" / "postings.bin"
    blob = victim.read_bytes()
    victim.write_bytes(blob[: len(blob) // 2])
    stats = _build(out, resume=True)
    assert stats.chunks_reused == 3 and stats.spills_reused == 2
    _assert_equals_uncrashed(out, tmp_path)


def test_crashed_resumed_equals_uncrashed_with_workers(tmp_path):
    """The resume path composes with multiprocess spilling: a run crashed
    under the injector, resumed with workers=2, still lands on the
    byte-identical tree (worker count never leaks into the §17.4 bytes)."""
    out = tmp_path / "out"
    inj = FaultInjector([FaultEvent("ingest.spill", "crash", shard=2)])
    with pytest.raises(ShardCrash):
        _build(out, injector=inj)
    _build(out, resume=True, workers=2)
    _assert_equals_uncrashed(out, tmp_path)
