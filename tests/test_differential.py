"""Differential property-test harness.

Pins the two contracts every engine-level refactor must preserve:

1. **Engine equivalence** — for random corpora and k-word queries (duplicate
   lemmas included), the §10 oracle, the scalar SE2.4 Combiner, the
   vectorized engine and the fused batched pipeline (and its Pallas-kernel
   path) return the SAME fragment sets.

2. **Incremental == rebuild** — after randomized add/delete/compact
   sequences, the multi-segment incremental index is byte-identical
   (``index_sets_equal``) to a from-scratch ``build_indexes`` over the
   surviving documents, and searching it returns byte-identical fragments
   across all engines.

3. **Snapshot/restore == live** — a DESIGN.md §12 snapshot of the
   post-ops indexer restores to an ``index_sets_equal``-identical index
   whose lazily decoded postings serve byte-identical fragments through
   every engine.

4. **Arena == host pack == oracle** — the DESIGN.md §13 device-resident
   posting arena serves byte-identical fragments to the host-pack path
   across live add/delete/compact sequences (generation bumps must evict
   stale device buffers) and under budget-forced partial residency
   (non-resident keys fall back to the host pack mid-batch).

5. **Device readout == host readout == oracle** — the §15.1 device-side
   result assembly (segmented sort + dedup on device, one fixed-shape D2H
   copy) equals the legacy host ``np.nonzero`` + dedup readout and the
   oracle, after randomized mutations, under budget-forced partial
   residency (mixed arena/host merge), and through a dead-shard fan-out.

Runs under real ``hypothesis`` (fixed seed via ``derandomize``) or the
deterministic shim — both bounded to a small example budget for CI.
"""

from __future__ import annotations

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st
from tests.strategies import make_corpus, make_op_sequence, make_queries, seeds

from repro.core.combiner import se24_combiner
from repro.core.keys import expand_subqueries, select_keys
from repro.core.oracle import oracle_search
from repro.index import DocumentStore, IncrementalIndexer, build_indexes, index_sets_equal
from repro.search.engine import SearchEngine
from repro.search.vectorized import VectorizedEngine


def _frag_set(results):
    return {(r.doc_id, r.start, r.end) for r in results}


def _response_frags(resp):
    return sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)


def _oracle_subquery(sub, index):
    keys = select_keys(sub, index.fl)
    postings = {k: index.key_postings(k.components) for k in keys}
    return oracle_search(sub, keys, postings, index.max_distance)


# ---------------------------------------------------------------------------
# 1. engine equivalence: oracle == SE2.4 == vectorized == fused (== kernel)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None, derandomize=True)
@given(seeds)
def test_engines_match_oracle(seed):
    spec = make_corpus(seed)
    store = DocumentStore.from_texts(spec.texts)
    index = build_indexes(
        store,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    vec = VectorizedEngine(index)
    fused = SearchEngine(index, lemmatizer=store.lemmatizer, algorithm="fused")
    for query in make_queries(seed, spec, n_queries=3):
        subqueries = expand_subqueries(query, store.lemmatizer)
        oracle_union = set()
        for sub in subqueries:
            oracle = _frag_set(_oracle_subquery(sub, index))
            scalar, _ = se24_combiner(sub, index)
            assert _frag_set(scalar) == oracle, (query, sub, "se2.4 != oracle")
            vec_res, _ = vec.search_subquery(sub)
            assert _frag_set(vec_res) == oracle, (query, sub, "vectorized != oracle")
            oracle_union |= oracle
        resp = fused.search(query, top_k=32)
        assert set(_response_frags(resp)) == oracle_union, (query, "fused != oracle")


@settings(max_examples=3, deadline=None, derandomize=True)
@given(seeds)
def test_kernel_engine_matches_oracle(seed):
    """The Pallas window-kernel path (dense on-device occupancy) against the
    oracle — fewer examples, it runs the kernel in interpret mode on CPU."""
    spec = make_corpus(seed, max_docs=8)
    store = DocumentStore.from_texts(spec.texts)
    index = build_indexes(
        store,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    kern = SearchEngine(
        index, lemmatizer=store.lemmatizer, algorithm="fused", use_kernel=True
    )
    for query in make_queries(seed, spec, n_queries=2):
        subqueries = expand_subqueries(query, store.lemmatizer)
        oracle_union = set()
        for sub in subqueries:
            oracle_union |= _frag_set(_oracle_subquery(sub, index))
        resp = kern.search(query, top_k=32)
        assert set(_response_frags(resp)) == oracle_union, (query, "kernel != oracle")


# ---------------------------------------------------------------------------
# 2. incremental multi-segment index == from-scratch rebuild
# ---------------------------------------------------------------------------


def _run_ops(spec, ops_seed):
    seq = make_op_sequence(ops_seed, spec)
    ix = IncrementalIndexer(
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    rng = np.random.default_rng(ops_seed)
    live: list[int] = []
    for batch, step in zip(seq.batches, seq.ops):
        live += ix.add_documents(batch)
        ix.commit()
        for op in step:
            if op[0] == "delete" and live:
                n_del = max(1, int(len(live) * op[1]))
                for _ in range(n_del):
                    victim = live.pop(int(rng.integers(len(live))))
                    ix.delete_document(victim)
            elif op[0] == "compact":
                ix.compact(memory_budget_bytes=op[1])
    ix.commit()
    return ix


@settings(max_examples=8, deadline=None, derandomize=True)
@given(seeds)
def test_incremental_matches_rebuild(seed):
    spec = make_corpus(seed)
    ix = _run_ops(spec, seed)
    equal, why = index_sets_equal(ix.index.to_index_set(), ix.rebuild_index_set())
    assert equal, why
    # and after a full compaction (single rewritten segment, tombstones GC'd)
    ix.compact()
    assert len(ix.segments) <= 1
    assert not ix.tombstones
    equal, why = index_sets_equal(ix.index.to_index_set(), ix.rebuild_index_set())
    assert equal, f"post-compact: {why}"


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seeds)
def test_snapshot_restore_matches_live_all_engines(seed):
    """DESIGN.md §12: after randomized add/delete/compact sequences, a
    snapshot restored in this process (mmap-backed lazy segments) is
    byte-identical to the live index and serves identical fragments
    through scalar SE2.4, vectorized, fused and kernel paths."""
    import tempfile

    spec = make_corpus(seed, max_docs=8)
    ix = _run_ops(spec, seed)
    snap_ctx = tempfile.TemporaryDirectory()
    with snap_ctx as snap_dir:
        ix.snapshot(snap_dir)
        rx = IncrementalIndexer.restore(snap_dir)
        _check_restored(ix, rx, spec, seed)


def _check_restored(ix, rx, spec, seed):
    equal, why = index_sets_equal(rx.index.to_index_set(), ix.index.to_index_set())
    assert equal, f"restored != live: {why}"
    store = ix.surviving_store()
    for query in make_queries(seed, spec, n_queries=2):
        for sub in expand_subqueries(query, store.lemmatizer):
            a, _ = se24_combiner(sub, ix.index)
            b, _ = se24_combiner(sub, rx.index)
            assert _frag_set(a) == _frag_set(b), (query, sub, "se2.4 restored != live")
            va, _ = VectorizedEngine(rx).search_subquery(sub)
            assert _frag_set(va) == _frag_set(a), (query, sub, "vectorized restored != live")
        for use_kernel in (False, True):
            ra = SearchEngine(
                ix, lemmatizer=store.lemmatizer, algorithm="fused", use_kernel=use_kernel
            ).search(query, top_k=32)
            rb = SearchEngine(
                rx, lemmatizer=store.lemmatizer, algorithm="fused", use_kernel=use_kernel
            ).search(query, top_k=32)
            assert _response_frags(ra) == _response_frags(rb), (
                query,
                f"fused(kernel={use_kernel}) restored != live",
            )


# ---------------------------------------------------------------------------
# 4. DESIGN.md §13: arena path == host-pack path == oracle, under mutation
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seeds)
def test_arena_matches_host_and_oracle_under_mutation(seed):
    """The device-resident arena serves byte-identical fragments to the
    host pack and the §10 oracle over the zipf corpora, including mid-run
    commit/delete/compact (generation bumps evict stale arena buffers via
    the mutation hook) and a budget that forces partial residency."""
    from repro.search.arena import PostingArena
    from repro.search.frontend import ServingFrontend

    spec = make_corpus(seed, max_docs=8)
    ix = _run_ops(spec, seed)
    store = ix.surviving_store()
    arena = PostingArena()
    arena.attach(ix)
    fa = ServingFrontend(ix, lemmatizer=store.lemmatizer, arena=arena)
    queries = make_queries(seed, spec, n_queries=2)

    def check(tag):
        st2 = ix.surviving_store()
        host = SearchEngine(ix, lemmatizer=st2.lemmatizer, algorithm="fused")
        for query in queries:
            ra = fa.search(query, top_k=32)
            rb = host.search(query, top_k=32)
            assert _response_frags(ra) == _response_frags(rb), (query, tag)
            oracle_union = set()
            for sub in expand_subqueries(query, st2.lemmatizer):
                oracle_union |= _frag_set(_oracle_subquery(sub, ix.index))
            assert set(_response_frags(ra)) == oracle_union, (query, tag, "oracle")

    check("post-ops")
    # live mutations between serves: the arena must track every generation
    ix.add_documents(["who are you who to be or not to be"])
    ix.commit()
    check("post-commit")
    victims = sorted(ix.documents)
    if victims:
        ix.delete_document(victims[len(victims) // 2])
    check("post-delete")
    ix.compact()
    check("post-compact")
    # budget-forced partial residency: roughly one family fits
    sizes = sorted(fb.nbytes for fb in arena._entries.values()) or [1024]
    tiny = PostingArena(budget_bytes=sizes[0] + 1)
    ft = ServingFrontend(ix, lemmatizer=store.lemmatizer, arena=tiny)
    check_host = SearchEngine(ix, lemmatizer=store.lemmatizer, algorithm="fused")
    for query in queries:
        ra = ft.search(query, top_k=32)
        rb = check_host.search(query, top_k=32)
        assert _response_frags(ra) == _response_frags(rb), (query, "partial-residency")


# ---------------------------------------------------------------------------
# 5. DESIGN.md §15.1: device readout == host readout == oracle
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seeds)
def test_device_readout_matches_host_and_oracle(seed):
    """The §15.1 device-assembled result buffer equals the legacy host
    ``np.nonzero`` + dedup readout and the §10 oracle — after randomized
    add/delete/compact sequences, under budget-forced partial residency
    (mixed arena/host merge, both readouts per sub-batch), and through a
    dead-shard fan-out (the sharded service's merge over per-shard device
    buffers)."""
    from functools import partial

    from repro.search import distributed as dist_mod
    from repro.search.arena import PostingArena
    from repro.search.distributed import ShardedSearchService
    from repro.search.fused import serve_query_batch

    spec = make_corpus(seed, max_docs=8)
    ix = _run_ops(spec, seed)
    store = ix.surviving_store()
    queries = make_queries(seed, spec, n_queries=2)
    work = [
        [(sub, ix.index) for sub in expand_subqueries(q, store.lemmatizer)]
        for q in queries
    ]

    def both_readouts(residencies=None, tag=""):
        dev, host = (
            serve_query_batch(
                work,
                max_distance=ix.index.max_distance,
                residencies=residencies,
                readout=mode,
            )
            for mode in ("device", "host")
        )
        for qi, q in enumerate(queries):
            got = _frag_set(dev.per_query[qi])
            assert got == _frag_set(host.per_query[qi]), (q, tag, "device != host")
            oracle_union = set()
            for sub in expand_subqueries(q, store.lemmatizer):
                oracle_union |= _frag_set(_oracle_subquery(sub, ix.index))
            assert got == oracle_union, (q, tag, "device != oracle")

    both_readouts(tag="host-pack")
    # full residency, then a budget that forces the mixed arena/host merge
    arena = PostingArena()
    res = arena.acquire(ix.index, 0)
    both_readouts({id(ix.index): res}, tag="arena")
    sizes = sorted(fb.nbytes for fb in arena._entries.values()) or [1024]
    arena.release()
    tiny = PostingArena(budget_bytes=sizes[0] + 1)
    both_readouts({id(ix.index): tiny.acquire(ix.index, 0)}, tag="partial")
    tiny.release()

    # dead-shard fan-out: the per-shard device buffers merge to exactly the
    # live shards' host-readout fragments
    n_shards = 2
    svc = ShardedSearchService(
        store,
        n_shards=n_shards,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
    )
    for q in queries:
        ra = svc.search(q, top_k=32, dead_shards=(1,))
        try:
            dist_mod.serve_query_batch = partial(serve_query_batch, readout="host")
            rb = svc.search(q, top_k=32, dead_shards=(1,))
        finally:
            dist_mod.serve_query_batch = serve_query_batch
        assert _response_frags(ra) == _response_frags(rb), (q, "dead-shard")
        assert all(d.doc_id % n_shards != 1 for d in ra.docs), (q, "dead shard leaked")


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seeds)
def test_incremental_serving_matches_rebuild_all_engines(seed):
    """Searching the live multi-segment view returns byte-identical fragments
    to a rebuilt index, across scalar SE2.4, vectorized, fused and kernel."""
    spec = make_corpus(seed, max_docs=8)
    ix = _run_ops(spec, seed)
    store = ix.surviving_store()
    rebuild = ix.rebuild_index_set()
    queries = make_queries(seed, spec, n_queries=2)
    for query in queries:
        subqueries = expand_subqueries(query, store.lemmatizer)
        for sub in subqueries:
            a, _ = se24_combiner(sub, ix.index)
            b, _ = se24_combiner(sub, rebuild)
            assert _frag_set(a) == _frag_set(b), (query, sub, "se2.4 view != rebuild")
            va, _ = VectorizedEngine(ix).search_subquery(sub)
            assert _frag_set(va) == _frag_set(b), (query, sub, "vectorized view != rebuild")
        for use_kernel in (False, True):
            ra = SearchEngine(
                ix, lemmatizer=store.lemmatizer, algorithm="fused", use_kernel=use_kernel
            ).search(query, top_k=32)
            rb = SearchEngine(
                rebuild, lemmatizer=store.lemmatizer, algorithm="fused", use_kernel=use_kernel
            ).search(query, top_k=32)
            assert _response_frags(ra) == _response_frags(rb), (
                query,
                f"fused(kernel={use_kernel}) view != rebuild",
            )
