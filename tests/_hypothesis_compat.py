"""Fallback for the optional ``hypothesis`` dev dependency.

The tier-1 suite must run green without optional packages (the serving
containers ship a minimal image).  When ``hypothesis`` is installed (see
``requirements-dev.txt``) tests get the real property-based machinery; when
it is missing, this shim provides API-compatible ``given`` / ``settings`` /
``strategies`` that draw ``max_examples`` deterministic pseudo-random
examples per test — a fixed-seed sampler, not a shrinking property engine,
but the same coverage style.

Usage in test modules::

    from tests._hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module naming
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elem.draw(rng) for _ in range(rng.randint(min_size, max_size))
                ]
            )

    def settings(max_examples=20, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                # deterministic per-test stream: repeatable failures
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strats), **kwargs)

            # drawn values fill the trailing params; hide them from pytest's
            # fixture resolution (real hypothesis does the same)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            wrapper.__signature__ = sig.replace(
                parameters=params[: len(params) - len(strats)]
            )
            return wrapper

        return deco
