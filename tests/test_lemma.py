"""Lemmatization + FL-list (paper §2)."""

import pytest

from repro.core.lemma import FLList, Lemmatizer, LemmaType, tokenize


def test_paper_multi_lemma_examples(lemmatizer):
    # §5: "who are you who" -> [who] [are, be] [you] [who]
    assert lemmatizer.lemmas("are") == ("are", "be")
    assert lemmatizer.lemmas("is") == ("be",)
    assert lemmatizer.lemmas("has") == ("have",)
    assert lemmatizer.lemmas("who") == ("who",)


def test_tokenize():
    assert tokenize("Who are you, is The Album?") == [
        "who", "are", "you", "is", "the", "album",
    ]


def test_fl_list_ordering():
    fl = FLList.from_frequencies({"you": 1000, "who": 500, "rare": 3},
                                 sw_count=2, fu_count=1)
    # §2: "you" < "who" because you is more frequent
    assert fl.number("you") < fl.number("who")
    assert fl.compare("you", "who") == -1
    assert fl.lemma_type("you") == LemmaType.STOP
    assert fl.lemma_type("who") == LemmaType.STOP
    assert fl.lemma_type("rare") == LemmaType.FREQUENTLY_USED


def test_fl_unknown_is_ordinary():
    fl = FLList.from_frequencies({"a": 10}, sw_count=1, fu_count=1)
    assert fl.lemma_type("zzz") == LemmaType.ORDINARY
    assert fl.number("zzz") == len(fl)


def test_suffix_rules(lemmatizer):
    assert lemmatizer.lemmas("albums") == ("album",)
    assert lemmatizer.lemmas("running")[0] == "run"
    assert lemmatizer.lemmas("cries") == ("cry",)
