"""Tier-1 enforcement of the documentation contract (ISSUE 3 satellite;
extended to the persistence layers by ISSUE 4).

Every public ``repro.search`` / ``repro.index`` / ``repro.checkpoint`` API
must state its paper-§ anchor, and every module its exactness contract —
checked by ``tools/docstring_audit.py`` (the same script the dedicated CI
step runs); plus the doctest examples embedded in the ranking spec.
"""

from __future__ import annotations

import doctest
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))


def test_public_api_docstrings_have_anchors_and_contracts():
    from tools.docstring_audit import audit

    problems = audit(verbose=False)
    assert not problems, "\n".join(problems)


def test_relevance_doctests():
    import repro.search.relevance as relevance

    result = doctest.testmod(relevance, verbose=False)
    assert result.attempted > 0, "ranking spec lost its doctest examples"
    assert result.failed == 0
