"""Index builder (§3), including the paper's D0/D1 example records."""

import numpy as np

from repro.core.lemma import Lemmatizer
from repro.index import DocumentStore, PAPER_EXAMPLE_DOCS, build_indexes


def _example_index(max_distance=5):
    # the third text pins the paper's FL order (be more frequent than who)
    # without adding any (be, who, who) postings — it contains no "who"
    texts = list(PAPER_EXAMPLE_DOCS) + ["is is is is is is"]
    store = DocumentStore.from_texts(texts)
    # make every lemma a stop lemma so all triples materialize
    return build_indexes(store, sw_count=10_000, fu_count=0,
                         max_distance=max_distance)


def test_paper_be_who_who_records():
    """§3: key (be, who, who) must contain exactly the paper's records:
    (0,3,-3,5), (1,4,-4,-1), (1,4,-1,2), (1,4,-4,2), (1,7,-4,-1)."""
    idx = _example_index()
    fl = idx.fl
    key = tuple(sorted(["be", "who", "who"], key=fl.number))
    rows = idx.key_postings(key)
    got = {tuple(int(x) for x in r) for r in rows}
    expected = {(0, 3, -3, 5), (1, 4, -4, -1), (1, 4, -1, 2), (1, 4, -4, 2),
                (1, 7, -4, -1)}
    assert expected <= got, f"missing: {expected - got}"
    # no duplicate unordered pairs: d1 < d2 for s == t keys
    for _, _, d1, d2 in got:
        assert d1 < d2


def test_paper_you_are_who_record():
    """§3: key (you, are, who) contains (0, 2, -1, -2)."""
    idx = _example_index()
    fl = idx.fl
    comps = sorted(["you", "are", "who"], key=fl.number)
    rows = idx.key_postings(tuple(comps))
    # the record anchored at "you" (position 2 in D0)
    anchored = {tuple(int(x) for x in r) for r in rows if r[0] == 0 and r[1] == 2}
    # depending on FL order the canonical anchor may differ; check the
    # paper's record when "you" is the most frequent
    if comps[0] == "you":
        assert (0, 2, -1, -2) in anchored or (0, 2, -2, -1) in anchored


def test_postings_sorted_and_within_distance():
    idx = _example_index(max_distance=5)
    for key, rows in list(idx.triple.items())[:200]:
        arr = np.asarray(rows)
        # §4 order: lexicographic over (ID, P, D1, D2)
        as_tuples = [tuple(r) for r in arr.tolist()]
        assert as_tuples == sorted(as_tuples)
        assert np.all(np.abs(arr[:, 2]) <= 5)
        assert np.all(np.abs(arr[:, 3]) <= 5)


def test_triple_keys_are_all_stop_and_canonical(small_index):
    fl = small_index.fl
    for (f, s, t) in list(small_index.triple)[:300]:
        assert fl.is_stop(f) and fl.is_stop(s) and fl.is_stop(t)
        assert fl.number(f) <= fl.number(s) <= fl.number(t)


def test_nsw_records_reference_stop_lemmas(small_index):
    fl = small_index.fl
    checked = 0
    for lemma, rec in list(small_index.nsw.items())[:20]:
        assert rec.offsets[-1] == len(rec.stop_lemma)
        assert np.all(np.abs(rec.distance) <= small_index.max_distance)
        for n in rec.stop_lemma[:50]:
            assert n < fl.sw_count  # FL-numbers of stop lemmas
        checked += 1
    assert checked


def test_pair_index_types(small_index):
    from repro.core.lemma import LemmaType

    fl = small_index.fl
    for (w, v) in list(small_index.pair)[:200]:
        assert fl.lemma_type(w) == LemmaType.FREQUENTLY_USED
        assert fl.lemma_type(v) in (LemmaType.FREQUENTLY_USED, LemmaType.ORDINARY)
        if fl.lemma_type(v) == LemmaType.FREQUENTLY_USED:
            assert fl.number(w) < fl.number(v)
