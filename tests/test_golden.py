"""Golden regression fixtures: the paper's worked examples as hand-checked
literals, so refactors cannot silently drift from the paper's semantics.

* §3  — the (be, who, who) three-component records over the example
        documents D0/D1, pinned as an EXACT set (not a superset check).
* §3  — the "you are who" record under a pinned FL order.
* §10.1–10.2 — the Lemma-table sweep on explicit event streams: capped
        per-lemma counts, shrink-from-the-left, duplicate-lemma
        multiplicities ("to be or not to be").
* end-to-end — engine fragment literals for the paper's running queries
        over D0/D1, identical across scalar SE2.4, vectorized and fused.
"""

import numpy as np
import pytest

from repro.core.keys import Subquery, expand_subqueries
from repro.core.oracle import sweep_events
from repro.index import DocumentStore, PAPER_EXAMPLE_DOCS, build_indexes
from repro.search.engine import SearchEngine
from repro.search.vectorized import VectorizedEngine


@pytest.fixture(scope="module")
def golden_index():
    # The third text pins the paper's FL order (be before who, who before
    # you) without adding any (be, who, who) postings — it contains no "who".
    texts = list(PAPER_EXAMPLE_DOCS) + ["is is is is is is"]
    store = DocumentStore.from_texts(texts)
    index = build_indexes(store, sw_count=10_000, fu_count=0, max_distance=5)
    return store, index


# ---------------------------------------------------------------------------
# §3 record sets
# ---------------------------------------------------------------------------


def test_golden_be_who_who_records_exact(golden_index):
    """The paper's §3 worked example, exactly: D0 = "Who are you is the
    album by The Who", D1 = "Who has reality, who is real, who is true"
    produce exactly these five (ID, P, D1, D2) records for (be, who, who)."""
    store, index = golden_index
    fl = index.fl
    assert fl.number("be") < fl.number("who")  # the paper's FL order
    key = tuple(sorted(["be", "who", "who"], key=fl.number))
    rows = {tuple(int(x) for x in r) for r in index.key_postings(key)}
    assert rows == {
        (0, 3, -3, 5),
        (1, 4, -4, -1),
        (1, 4, -4, 2),
        (1, 4, -1, 2),
        (1, 7, -4, -1),
    }
    # s == t: the (d1, d2) pairs enumerate unordered distinct occurrences
    for _, _, d1, d2 in rows:
        assert d1 < d2


def test_golden_who_are_you_record(golden_index):
    """§3's "you are who" example record, canonicalized under this corpus'
    FL order (who < are < you): one record anchored at who@0 in D0."""
    store, index = golden_index
    fl = index.fl
    key = tuple(sorted(["you", "are", "who"], key=fl.number))
    assert key == ("who", "are", "you")
    assert [tuple(int(x) for x in r) for r in index.key_postings(key)] == [(0, 0, 1, 2)]


# ---------------------------------------------------------------------------
# §10.1–10.2 Lemma-table sweep
# ---------------------------------------------------------------------------


def test_golden_sweep_duplicate_multiplicities():
    """"to be or not to be": every lemma must meet its multiplicity (to=2,
    be=2, or=1, not=1); the only minimal covering fragment is [0..5]."""
    events = [(0, "to"), (1, "be"), (2, "or"), (3, "not"), (4, "to"), (5, "be")]
    out = sweep_events(7, events, {"to": 2, "be": 2, "or": 1, "not": 1}, max_span=10)
    assert [(r.doc_id, r.start, r.end) for r in out] == [(7, 0, 5)]


def test_golden_sweep_shrinks_from_left():
    """D0's event stream for [who][be][you]: the sweep emits at every
    covering position after dropping over-counted front lemmas —
    (0,2) on completion, (0,3) when the extra 'be' arrives (front 'who' is
    not over-counted), and (2,8) after both 'who'@0 and 'be'@1 are shed."""
    events = [(0, "who"), (1, "be"), (2, "you"), (3, "be"), (8, "who")]
    out = sweep_events(0, events, {"who": 1, "be": 1, "you": 1}, max_span=10)
    assert [(r.doc_id, r.start, r.end) for r in out] == [(0, 0, 2), (0, 0, 3), (0, 2, 8)]


def test_golden_sweep_respects_max_span():
    events = [(0, "a"), (1, "b"), (20, "a"), (21, "b")]
    out = sweep_events(1, events, {"a": 1, "b": 1}, max_span=4)
    assert [(r.start, r.end) for r in out] == [(0, 1), (20, 21)]


# ---------------------------------------------------------------------------
# end-to-end engine literals over the paper documents
# ---------------------------------------------------------------------------

GOLDEN_QUERY_FRAGMENTS = {
    # "who are you": subqueries [who][are][you] + [who][be][you]; fragments
    # are key-derivable events only (the (who@8, are@1, you@2) combination
    # exceeds MaxDistance from any anchor and is correctly absent).
    "who are you": [(0, 0, 2), (0, 0, 3), (0, 2, 8)],
    # "who are you who": who must occur twice -> the single minimal fragment
    # spans the whole of D0.
    "who are you who": [(0, 0, 8)],
}


@pytest.mark.parametrize("query,expected", sorted(GOLDEN_QUERY_FRAGMENTS.items()))
def test_golden_engine_fragments(golden_index, query, expected):
    store, index = golden_index
    for algorithm in ("se2.4", "fused"):
        resp = SearchEngine(index, lemmatizer=store.lemmatizer, algorithm=algorithm).search(
            query, top_k=10
        )
        frags = sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)
        assert frags == expected, (query, algorithm)
    vec = VectorizedEngine(index)
    union = set()
    for sub in expand_subqueries(query, store.lemmatizer):
        res, _ = vec.search_subquery(sub)
        union |= {(r.doc_id, r.start, r.end) for r in res}
    assert sorted(union) == expected, (query, "vectorized")
