"""Unit tests for the §14 ranking spec (``search/relevance.py``).

Pins the documented ordering contract: decreasing score, doc_id ascending on
ties, fragments sorted by (start, end), input-order-independent float sums,
and the empty/degenerate cases.
"""

from __future__ import annotations

import random

from repro.core.postings import SearchResult
from repro.search.relevance import fragment_score, rank_documents


def test_fragment_score_examples():
    assert fragment_score(SearchResult(0, 5, 5)) == 1.0  # single word
    assert fragment_score(SearchResult(0, 3, 4)) == 0.25  # span 1
    assert fragment_score(SearchResult(0, 0, 9)) == 1.0 / 100.0


def test_empty_and_degenerate_inputs():
    assert rank_documents([]) == []
    assert rank_documents(set()) == []
    assert rank_documents([SearchResult(1, 0, 1)], top_k=0) == []
    assert rank_documents([SearchResult(1, 0, 1)], top_k=-3) == []


def test_score_ties_break_by_ascending_doc_id():
    # four docs with identical fragment shapes -> identical scores
    frags = [SearchResult(d, 0, 2) for d in (9, 2, 7, 4)]
    ranked = rank_documents(frags, top_k=3)
    assert [doc for doc, _, _ in ranked] == [2, 4, 7]  # tie -> doc_id asc
    scores = {score for _, score, _ in ranked}
    assert len(scores) == 1  # genuinely tied


def test_fragments_sorted_within_document():
    frags = [
        SearchResult(5, 10, 12),
        SearchResult(5, 0, 3),
        SearchResult(5, 4, 4),
    ]
    ((doc, _, out),) = rank_documents(frags, top_k=1)
    assert doc == 5
    assert [(f.start, f.end) for f in out] == [(0, 3), (4, 4), (10, 12)]


def test_ranking_is_input_order_independent():
    """Scores are float sums; the documented contract is that summation runs
    in sorted fragment order, so every permutation (and set iteration order)
    yields bit-identical scores and ranking."""
    rng = random.Random(7)
    frags = list(
        {
            SearchResult(doc_id=d, start=s, end=s + span)
            for d in range(12)
            for s, span in [
                (rng.randrange(50), rng.randrange(9)) for _ in range(17)
            ]
        }
    )
    baseline = rank_documents(sorted(frags), top_k=12)
    for _ in range(10):
        shuffled = frags[:]
        rng.shuffle(shuffled)
        assert rank_documents(shuffled, top_k=12) == baseline
    assert rank_documents(set(frags), top_k=12) == baseline


def test_top_k_cut_is_deterministic_under_boundary_ties():
    # two tied docs straddle the top_k boundary: the cut keeps the lower id
    frags = [SearchResult(3, 0, 1), SearchResult(8, 10, 11), SearchResult(1, 4, 4)]
    ranked = rank_documents(frags, top_k=2)
    assert [doc for doc, _, _ in ranked] == [1, 3]  # 1.0 first, then tie 3 < 8
