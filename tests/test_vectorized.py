"""Vectorized engine == faithful Combiner == Pallas kernel (3-tier equality),
plus hypothesis properties for the closed-form window cover."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.combiner import se24_combiner
from repro.core.keys import expand_subqueries
from repro.core.oracle import sweep_events
from repro.core.window import window_cover, results_from_cover
from repro.search.vectorized import VectorizedEngine

QUERIES = ["who are you who", "to be or not to be", "what do you do all day"]


@pytest.mark.parametrize("query", QUERIES)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_three_tier_equality(query, use_kernel, small_index, lemmatizer):
    eng = VectorizedEngine(small_index, use_kernel=use_kernel)
    for sub in expand_subqueries(query, lemmatizer)[:2]:
        scalar, _ = se24_combiner(sub, small_index)
        vec, _ = eng.search_subquery(sub)
        assert sorted(set(scalar)) == sorted(set(vec))


@settings(max_examples=40, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 4),  # active lemmas
    st.integers(1, 2),  # max multiplicity
)
def test_window_cover_equals_sweep(seed, n_lemmas, max_mult):
    """Closed-form cover == the §10 sweep, for random occupancy."""
    rng = np.random.default_rng(seed)
    N, D = 96, 4
    occ = (rng.random((n_lemmas, N)) < 0.2).astype(np.int32)
    mult = rng.integers(1, max_mult + 1, n_lemmas).astype(np.int32)
    emit, start = window_cover(jnp.asarray(occ), jnp.asarray(mult), window=2 * D + 1)
    got = set(results_from_cover(0, np.asarray(emit), np.asarray(start)))

    events = sorted(
        (p, f"l{l}") for l in range(n_lemmas) for p in np.nonzero(occ[l])[0]
    )
    mult_map = {f"l{l}": int(mult[l]) for l in range(n_lemmas)}
    expected = {
        (0, r.start, r.end)
        for r in sweep_events(0, events, mult_map, max_span=2 * D)
    }
    assert got == expected


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_window_cover_dtype_equivalence(seed):
    rng = np.random.default_rng(seed)
    occ = (rng.random((3, 128)) < 0.15).astype(np.uint8)
    mult = np.array([1, 2, 1], np.int32)
    e8, s8 = window_cover(jnp.asarray(occ, jnp.uint8), jnp.asarray(mult), 11)
    e32, s32 = window_cover(jnp.asarray(occ, jnp.int32), jnp.asarray(mult), 11)
    assert bool(jnp.all(e8 == e32))
    assert bool(jnp.all(jnp.where(e32, s8, 0) == jnp.where(e32, s32, 0)))
