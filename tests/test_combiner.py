"""The Combiner (SE2.4) and baselines vs their oracles, incl. the §13 trace."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.baselines import (
    se1_ordinary,
    se21_main_cell,
    se22_intermediate,
    se23_optimized,
    simple_key_cover,
)
from repro.core.combiner import CombinerState, se24_combiner
from repro.core.keys import Subquery, expand_subqueries, select_keys
from repro.core.lemma import Lemmatizer
from repro.core.oracle import key_events, oracle_search, sweep_events
from repro.index import DocumentStore, build_indexes

QUERIES = [
    "who are you who",
    "to be or not to be",
    "the time of war",
    "what do you do all day",
    "time and time again",
]


def _oracle(sub, keys, idx, honor_stars=True):
    post = {k: idx.key_postings(k.components) for k in keys}
    mult = sub.multiplicity()
    out = []
    for d, ev in sorted(key_events(keys, post, honor_stars=honor_stars).items()):
        out.extend(sweep_events(d, ev, mult, max_span=2 * idx.max_distance))
    return sorted(out)


@pytest.mark.parametrize("query", QUERIES)
def test_se24_matches_oracle(query, small_index, lemmatizer):
    for sub in expand_subqueries(query, lemmatizer)[:2]:
        keys = select_keys(sub, small_index.fl)
        expected = _oracle(sub, keys, small_index)
        got, stats = se24_combiner(sub, small_index)
        assert sorted(got) == expected
        assert stats.intermediate_records == 0  # the paper's selling point


@pytest.mark.parametrize("query", QUERIES)
def test_se23_matches_its_oracle(query, small_index, lemmatizer):
    for sub in expand_subqueries(query, lemmatizer)[:2]:
        keys = select_keys(sub, small_index.fl)
        expected = _oracle(sub, keys, small_index, honor_stars=False)
        got, stats = se23_optimized(sub, small_index)
        assert sorted(got) == expected
        assert stats.intermediate_records > 0  # it DOES materialize streams


@pytest.mark.parametrize("query", QUERIES)
def test_se22_matches_its_oracle(query, small_index, lemmatizer):
    for sub in expand_subqueries(query, lemmatizer)[:2]:
        keys = simple_key_cover(sub, small_index.fl)
        expected = _oracle(sub, keys, small_index)
        got, _ = se22_intermediate(sub, small_index)
        assert sorted(got) == expected


def test_se1_superset_of_se24(small_index, lemmatizer):
    """SE1 merges full ordinary posting lists: it can only find MORE."""
    for query in QUERIES:
        for sub in expand_subqueries(query, lemmatizer)[:1]:
            r1, s1 = se1_ordinary(sub, small_index)
            r24, s24 = se24_combiner(sub, small_index)
            assert set(r24) <= set(r1)
            if s24.postings_read and s1.postings_read:
                assert s24.postings_read <= s1.postings_read


def test_paper_trace_section_13():
    """§13 incremental example: MaxDistance=7, WindowSize=14, Start=4;
    query [who][i][need][you]; first emitted result must be (15, 21)."""
    sub = Subquery(("who", "i", "need", "you"))
    state = CombinerState(sub, window_size=14, max_distance=7)
    state.shift(4)
    # postings of key (i, need, who): (19, 20, 15) — Set all three
    state.set(19, "i")
    state.set(20, "need")
    state.set(15, "who")
    # postings of key (you, need*, who*): only the 'you' component Sets
    state.set(21, "you")
    state.set(21, "you")
    state.set(22, "you")
    state.set(22, "you")
    state.process_source(doc_id=0)  # flush buffer 0 -> (15, who)
    assert [r for r in state.results] == []
    state.switch()  # Start = 18
    state.process_source(doc_id=0)  # flush former buffer 1 -> 19,20,21,22
    assert state.results, "trace must emit a result"
    first = state.results[0]
    assert (first.start, first.end) == (15, 21)


def test_duplicate_lemma_multiplicity(small_index, lemmatizer):
    """'to be or not to be' requires two 'to' and two 'be' in a fragment."""
    sub = expand_subqueries("to be or not to be", lemmatizer)[0]
    results, _ = se24_combiner(sub, small_index)
    docs = {d.doc_id: d for d in []}
    for r in results:
        # reconstruct the fragment lemma counts from the corpus
        pass  # structural assertion below via the oracle equality test
    keys = select_keys(sub, small_index.fl)
    assert sorted(results) == _oracle(sub, keys, small_index)


# ---------------------------------------------------------------------------
# property: tight synthetic clusters are always found
# ---------------------------------------------------------------------------

WORDS = ["alpha", "beta", "gamma", "delta"]


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(0, 3), min_size=3, max_size=4),  # query lemma ids
    st.integers(0, 1000),  # seed
)
def test_tight_clusters_always_found(query_ids, seed):
    """Documents whose query lemmas co-occur within MaxDistance/2 produce a
    key posting for every selected key, so SE2.4 == oracle exactly."""
    rng = np.random.default_rng(seed)
    query = [WORDS[i] for i in query_ids]
    texts = []
    for _ in range(6):
        filler = [f"x{rng.integers(20)}" for _ in range(30)]
        pos = int(rng.integers(5, 20))
        # inject the query words consecutively (distance < MaxDistance/2)
        doc = filler[:pos] + list(rng.permutation(query)) + filler[pos:]
        texts.append(" ".join(doc))
    store = DocumentStore.from_texts(texts)
    idx = build_indexes(store, sw_count=10_000, fu_count=0, max_distance=5)
    sub = Subquery(tuple(query))
    keys = select_keys(sub, idx.fl)
    post = {k: idx.key_postings(k.components) for k in keys}
    expected = oracle_search(sub, keys, post, idx.max_distance)
    got, _ = se24_combiner(sub, idx)
    assert sorted(got) == sorted(expected)
    assert len(got) >= 6  # every injected cluster found


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_random_corpus_se24_equals_oracle(seed):
    """On arbitrary Zipf corpora SE2.4 must equal its oracle (the Step-2
    gate may only skip fragments no key posting covers — which the oracle,
    built from the same postings, also cannot see)."""
    rng = np.random.default_rng(seed)
    vocab = [f"w{i}" for i in range(15)]
    probs = np.array([1 / (i + 1) ** 1.1 for i in range(15)])
    probs /= probs.sum()
    texts = [
        " ".join(rng.choice(vocab, size=60, p=probs)) for _ in range(8)
    ]
    store = DocumentStore.from_texts(texts)
    idx = build_indexes(store, sw_count=10_000, fu_count=0, max_distance=4)
    q = list(rng.choice(vocab[:6], size=3, replace=True))
    sub = Subquery(tuple(q))
    keys = select_keys(sub, idx.fl)
    post = {k: idx.key_postings(k.components) for k in keys}
    expected = oracle_search(sub, keys, post, idx.max_distance)
    got, _ = se24_combiner(sub, idx)
    assert sorted(got) == sorted(expected)


def test_se24_multi_lemma_position_counts_both_lemmas():
    """Regression (PR 3): a §2 multi-lemma word ("are" -> are, be) satisfies
    TWO subquery lemmas at one position.  The verbatim §10.3 Set-overwrite
    dropped one of them, so SE2.4 missed the minimal fragment whose "be" is
    supplied by the word "are" and emitted a longer stale-start fragment
    instead — diverging from the oracle (and the device engines, which were
    already event-exact).  Pins the atomic-position lemma-set fix."""
    lem = Lemmatizer()
    texts = [
        # positions:  0  1   2   3   4
        "when be of to who are you who",
        # the minimal fragment for [to be who you are] is [3..6]:
        # to(3) who(4) are+be(5) you(6) — "be" comes from the word "are"
    ]
    store = DocumentStore.from_texts(texts, lemmatizer=lem)
    idx = build_indexes(store, sw_count=30, fu_count=30, max_distance=5)
    sub = expand_subqueries("to be who you are", lem)[0]
    assert sub.lemmas == ("to", "be", "who", "you", "are")
    keys = select_keys(sub, idx.fl)
    expected = _oracle(sub, keys, idx)
    got, _ = se24_combiner(sub, idx)
    assert sorted(got) == expected
    frags = {(r.doc_id, r.start, r.end) for r in got}
    assert (0, 3, 6) in frags, "the multi-lemma-position minimal fragment"
