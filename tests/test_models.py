"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finite values (assignment requirement)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced_spec
from repro.models import gnn, recsys, transformer

LM_ARCHS = ["stablelm-3b", "mistral-large-123b", "tinyllama-1.1b",
            "llama4-maverick-400b-a17b", "olmoe-1b-7b"]
RECSYS_ARCHS = ["autoint", "mind", "dcn-v2", "fm"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    spec = get_reduced_spec(arch)
    cfg = spec.model_cfg
    params = transformer.init_params(jax.random.key(0), cfg)
    B, S = 2, 64
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "targets": jnp.ones((B, S), jnp.int32),
        "mask": jnp.ones((B, S), jnp.int32),
    }
    loss, aux = transformer.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    # prefill -> decode roundtrip
    logits, cache = transformer.prefill_step(params, batch["tokens"], cfg)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert cache["k"].shape == (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.d_head)
    k = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
    v = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
    lg2, cache2 = transformer.decode_step(
        params, {"k": k, "v": v}, jnp.zeros((B, 1), jnp.int32),
        jnp.asarray(S, jnp.int32), cfg,
    )
    assert lg2.shape == (B, cfg.vocab) and np.isfinite(np.asarray(lg2)).all()
    assert cache2["k"].shape == k.shape


def test_lm_param_count_sanity():
    from repro.configs import get_spec

    # full-scale parameter counts should be near the advertised sizes
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "mistral-large-123b": (115e9, 130e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_spec(arch).model_cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e}"
    a17 = get_spec("llama4-maverick-400b-a17b").model_cfg.active_param_count()
    assert a17 < 40e9  # top-1 routing: far below total


@pytest.mark.parametrize("shape", ["full_graph_sm", "molecule"])
def test_gnn_smoke(shape):
    spec = get_reduced_spec("gat-cora")
    kw = spec.shapes[shape].kwargs
    cfg = spec.cfg_for(shape)
    params = gnn.init_gat_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    n, e = kw["n_nodes"], kw["n_edges"]
    ng = kw.get("batch_graphs", 1)
    task_graph = kw["task"] == "graph"
    nl = ng if task_graph else n
    batch = {
        "x": jnp.asarray(rng.normal(size=(n, kw["d_feat"])), jnp.float32),
        "src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_mask": jnp.ones((e,), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, kw["n_classes"], nl), jnp.int32),
        "label_mask": jnp.ones((nl,), jnp.int32),
    }
    if task_graph:
        batch["graph_ids"] = jnp.asarray(np.repeat(np.arange(ng), n // ng), jnp.int32)
    loss, metrics = gnn.gat_loss(params, batch, cfg, n_graphs=ng)
    assert np.isfinite(float(loss)) and 0.0 <= float(metrics["acc"]) <= 1.0
    out = gnn.gat_forward(params, batch, cfg, n_graphs=ng)
    assert out.shape == ((ng if task_graph else n), kw["n_classes"])


def test_gnn_edge_mask_excludes_padding():
    """Padded edges must not change the output."""
    spec = get_reduced_spec("gat-cora")
    cfg = spec.cfg_for("full_graph_sm")
    params = gnn.init_gat_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(3)
    n, e, f = 32, 64, spec.shapes["full_graph_sm"].kwargs["d_feat"]
    base = {
        "x": jnp.asarray(rng.normal(size=(n, f)), jnp.float32),
        "src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_mask": jnp.ones((e,), jnp.int32),
    }
    out1 = gnn.gat_forward(params, base, cfg)
    padded = dict(base)
    padded["src"] = jnp.concatenate([base["src"], jnp.zeros(16, jnp.int32)])
    padded["dst"] = jnp.concatenate([base["dst"], jnp.zeros(16, jnp.int32)])
    padded["edge_mask"] = jnp.concatenate([base["edge_mask"], jnp.zeros(16, jnp.int32)])
    out2 = gnn.gat_forward(params, padded, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    spec = get_reduced_spec(arch)
    cfg = spec.model_cfg
    params = recsys.init_recsys_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(1)
    B = 16
    if cfg.model == "mind":
        batch = {
            "hist_ids": jnp.asarray(rng.integers(-1, 100, (B, cfg.hist_len)), jnp.int32),
            "target_id": jnp.asarray(rng.integers(0, 100, B), jnp.int32),
        }
    else:
        batch = {
            "sparse_ids": jnp.asarray(rng.integers(0, 4, (B, cfg.n_sparse)), jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, B), jnp.float32),
        }
        if cfg.n_dense:
            batch["dense"] = jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32)
    loss, _ = recsys.recsys_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    rb = {"cand_ids": jnp.asarray(rng.integers(0, 100, 64), jnp.int32)}
    if cfg.model == "mind":
        rb["hist_ids"] = batch["hist_ids"][:1]
    else:
        rb["sparse_ids"] = batch["sparse_ids"][:1]
        if cfg.n_dense:
            rb["dense"] = batch["dense"][:1]
    scores = recsys.recsys_retrieval_score(params, rb, cfg)
    assert scores.shape == (64,) and np.isfinite(np.asarray(scores)).all()


def test_fm_sum_square_trick():
    """FM pairwise term via sum-square == explicit O(n^2) pairwise sum."""
    spec = get_reduced_spec("fm")
    cfg = spec.model_cfg
    params = recsys.init_recsys_params(jax.random.key(2), cfg)
    rng = np.random.default_rng(2)
    ids = rng.integers(0, 4, (4, cfg.n_sparse)).astype(np.int32)
    got = np.asarray(recsys.recsys_score(params, {"sparse_ids": jnp.asarray(ids)}, cfg))
    table = np.asarray(params["table"], np.float64)
    wlin = np.asarray(params["w_linear"], np.float64)
    off = np.asarray(cfg.field_offsets)
    for b in range(4):
        rows = ids[b] + off
        v = table[rows]
        lin = wlin[rows].sum()
        pair = 0.0
        for i in range(len(rows)):
            for j in range(i + 1, len(rows)):
                pair += float(v[i] @ v[j])
        np.testing.assert_allclose(got[b], float(params["w0"]) + lin + pair, rtol=2e-3)


def test_embedding_bag_modes():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([[0, 1, -1], [2, -1, -1]], jnp.int32)
    s = np.asarray(recsys.embedding_bag(table, ids, mode="sum"))
    m = np.asarray(recsys.embedding_bag(table, ids, mode="mean"))
    np.testing.assert_allclose(s[0], [2.0, 4.0])
    np.testing.assert_allclose(m[0], [1.0, 2.0])
    np.testing.assert_allclose(s[1], [4.0, 5.0])
    np.testing.assert_allclose(m[1], [4.0, 5.0])


def test_sliding_window_decode_matches_full_when_window_covers():
    """SWA decode == full decode while the cache fits in the window."""
    spec = get_reduced_spec("tinyllama-1.1b")
    import dataclasses

    cfg = spec.model_cfg
    params = transformer.init_params(jax.random.key(3), cfg)
    B, S = 2, 48
    toks = jnp.zeros((B, S), jnp.int32)
    _, cache = transformer.prefill_step(params, toks, cfg)
    k = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 80), (0, 0), (0, 0)))
    v = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 80), (0, 0), (0, 0)))
    tok = jnp.zeros((B, 1), jnp.int32)
    full, _ = transformer.decode_step(params, {"k": k, "v": v}, tok,
                                      jnp.asarray(S, jnp.int32), cfg)
    cfg_swa = dataclasses.replace(cfg, sliding_window=64)
    swa, _ = transformer.decode_step(params, {"k": k, "v": v}, tok,
                                     jnp.asarray(S, jnp.int32), cfg_swa)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa), rtol=2e-2, atol=2e-2)
