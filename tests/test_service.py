"""End-to-end tests for the §16 continuous-batching serving daemon.

Concurrent clients over the in-process transport (and the JSON-lines TCP
transport) against the resilient sharded stack under seeded chaos
schedules (``FaultInjector.from_seed`` — the same §14 schedules the chaos
harness replays): every served response must be SE2.4-oracle-exact over
the full corpus or explicitly flagged partial with exact coverage of
whole shards — never silently wrong — no matter how requests interleave,
batch or queue.  Plus: replica-routing consistency across a mid-run
commit and compact (one §12.5 generation lineage, so no replica can serve
a stale cache entry as fresh), and a lossless TCP round trip (wire docs
identical to the in-process response).
"""

from __future__ import annotations

import threading

import pytest

from tests.test_chaos import (
    CHAOS_SEEDS,
    N_SHARDS,
    TOP_K,
    _build_stack,
    _oracle_union,
    _ranking,
    _response_frags,
)

from repro.index import IncrementalIndexer
from repro.runtime.clock import ManualClock
from repro.search.frontend import SearchRequest, ServingFrontend
from repro.search.service import (
    ServiceDaemon,
    request_over_tcp,
    response_to_wire,
    serve_tcp,
)


def _assert_exact_or_flagged_frags(resp, oracle):
    """The §14 invariant, assertable without the live excluded-shard set
    (responses may be checked after later rounds changed it): a response
    is the full oracle, or it is flagged partial AND exactly the oracle
    minus whole shards (with exact ranking over what it covers)."""
    got = _response_frags(resp)
    if got == oracle:
        return
    assert resp.stats.partial, (resp.query, "divergent response not flagged")
    dead = {
        s
        for s in range(N_SHARDS)
        if any(f[0] % N_SHARDS == s for f in oracle)
        and not any(f[0] % N_SHARDS == s for f in got)
    }
    expected = {f for f in oracle if f[0] % N_SHARDS not in dead}
    assert got == expected, (resp.query, sorted(dead), "not whole-shard coverage")
    assert [(d.doc_id, d.score) for d in resp.docs] == _ranking(expected), (
        resp.query,
        "degraded ranking is not the exact ranking of the covered set",
    )


# ---------------------------------------------------------------------------
# concurrent clients x seeded chaos, in-process transport
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_concurrent_clients_under_chaos_exact_or_flagged(chaos_seed, tmp_path):
    """N threaded clients against the started daemon while the seeded §14
    fault schedule fires (crashes, a kill + snapshot recovery, stragglers,
    bit-flips): every one of the N*rounds*queries responses is oracle-exact
    or flagged with whole-shard coverage."""
    svc, queries, oracles = _build_stack(tmp_path, chaos_seed=chaos_seed)
    daemon = ServiceDaemon(ServingFrontend(svc), max_queue=512).start()
    n_clients, rounds = 4, 3
    results: list[list] = [[] for _ in range(n_clients)]
    errors: list[BaseException] = []

    def client(c: int) -> None:
        try:
            for _ in range(rounds):
                tickets = [
                    daemon.submit(SearchRequest(q, top_k=TOP_K)) for q in queries
                ]
                for q, t in zip(queries, tickets):
                    results[c].append((q, t.result(timeout=120.0)))
        except BaseException as e:  # surface in the main thread
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,)) for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300.0)
    daemon.stop()
    assert not errors, errors
    served = [pair for per_client in results for pair in per_client]
    assert len(served) == n_clients * rounds * len(queries)
    for q, resp in served:
        _assert_exact_or_flagged_frags(resp, oracles[q])
    m = daemon.metrics()
    assert m["submitted"] == m["completed"] + m["shed_queue"]
    assert m["shed_queue"] == 0  # queue was large enough: nothing dropped


def test_deterministic_chaos_replay_through_daemon(tmp_path):
    """The same chaos stack driven by the virtual-clock replay: two runs
    of one seed produce identical response traces through the daemon
    (the §14 determinism contract lifted to the service layer)."""

    def run(subdir):
        clock = ManualClock()
        svc, queries, _ = _build_stack(
            tmp_path / subdir, chaos_seed=CHAOS_SEEDS[0], clock=clock
        )
        daemon = ServiceDaemon(
            ServingFrontend(svc, clock=clock), clock=clock, max_queue=512
        )
        schedule = [
            (i * 0.001, SearchRequest(q, top_k=TOP_K))
            for i, q in enumerate(queries * 6)
        ]
        tickets = daemon.replay(schedule, service_time_sec=0.004)
        return [
            (
                sorted(_response_frags(t.result(timeout=0))),
                t.result(timeout=0).stats.shards_degraded,
                t.result(timeout=0).stats.partial,
                t.batch_size,
            )
            for t in tickets
        ]

    assert run("a") == run("b")


# ---------------------------------------------------------------------------
# replica routing across a mid-run commit/compact
# ---------------------------------------------------------------------------


def test_replica_routing_consistent_across_commit_and_compact(small_corpus):
    """Two frontend replicas over ONE incremental source: after a mid-run
    commit and a later compact, every response from EITHER replica equals
    the fresh single-frontend reference for the live index state — the
    shared §12.5 generation lineage makes pre-mutation cache entries
    unreachable on both replicas, so routing never changes results."""
    ix = IncrementalIndexer(
        sw_count=60, fu_count=150, max_distance=5,
        lemmatizer=small_corpus.lemmatizer,
    )
    ix.add_documents([d.text for d in small_corpus.documents])
    ix.commit()
    clock = ManualClock()
    replicas = [
        ServingFrontend(ix, lemmatizer=small_corpus.lemmatizer,
                        max_batch=2, clock=clock)
        for _ in range(2)
    ]
    daemon = ServiceDaemon(replicas, clock=clock, max_queue=64)
    queries = ["who are you who", "to be or not to be", "the who", "you do"]

    def serve_all():
        tickets = [daemon.submit(SearchRequest(q, top_k=50)) for q in queries]
        daemon.drain()
        return [t.result(timeout=0) for t in tickets]

    def reference():
        fe = ServingFrontend(ix, lemmatizer=small_corpus.lemmatizer)
        return {q: fe.search(q, top_k=50) for q in queries}

    def frags(resp):
        return sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)

    # round 1: both replicas warm their caches on the initial generation
    for resp, (q, want) in zip(serve_all(), reference().items()):
        assert frags(resp) == frags(want), q

    # mid-run commit: new docs, token bump on the shared lineage
    ix.add_documents(["who are you who are you", "to be or not to be at all"])
    ix.commit()
    want = reference()
    got = serve_all() + serve_all()  # twice: hit both replicas for sure
    for resp in got:
        assert frags(resp) == frags(want[resp.query]), (resp.query, "stale post-commit")
        assert resp.stats.shards_degraded == 0

    # mid-run compact (delete + rewrite): token bumps again
    victim = next(r for r in got if r.docs).docs[0].doc_id
    ix.delete_document(victim)
    ix.compact()
    want = reference()
    for resp in serve_all() + serve_all():
        assert frags(resp) == frags(want[resp.query]), (resp.query, "stale post-compact")
        assert victim not in [d.doc_id for d in resp.docs]
    m = daemon.metrics()
    assert all(n > 0 for n in m["per_replica_batches"]), m


# ---------------------------------------------------------------------------
# TCP transport: lossless round trip, concurrent connections
# ---------------------------------------------------------------------------


def test_tcp_round_trip_is_lossless_and_concurrent(small_index, lemmatizer):
    """The JSON-lines wire image of a response equals response_to_wire of
    the in-process reference (docs, scores, fragments, flags), for several
    concurrent client connections; the metrics op reports the daemon's
    counters over the same socket."""
    frontend = ServingFrontend(small_index, lemmatizer=lemmatizer, max_batch=8)
    daemon = ServiceDaemon(frontend, max_queue=64)
    server = serve_tcp(daemon)  # ephemeral port
    try:
        queries = ["who are you who", "to be or not to be", "what do you do all day"]
        reference = ServingFrontend(small_index, lemmatizer=lemmatizer)
        want = {
            q: response_to_wire(reference.search(q, top_k=8)) for q in queries
        }

        outs: dict[str, dict] = {}
        errors: list[BaseException] = []

        def client(q: str) -> None:
            try:
                outs[q] = request_over_tcp(
                    server.address, {"query": q, "top_k": 8}
                )
            except BaseException as e:
                errors.append(e)

        threads = [threading.Thread(target=client, args=(q,)) for q in queries]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120.0)
        assert not errors, errors
        for q in queries:
            got = outs[q]
            assert got["docs"] == want[q]["docs"], q
            assert not got["partial"] and not got["shed"]
            assert got["batch_size"] >= 1

        m = request_over_tcp(server.address, {"op": "metrics"})["metrics"]
        assert m["completed"] == len(queries)
        assert m["submitted"] == m["completed"] + m["shed_queue"]
        assert request_over_tcp(server.address, {"op": "ping"}) == {"pong": True}
    finally:
        server.shutdown()
        server.server_close()
        daemon.stop()


def test_tcp_deadline_and_bad_requests(small_index, lemmatizer):
    """deadline_ms crosses the wire into the §5 partial machinery (a zero
    budget returns an empty flagged response), and malformed lines get an
    error reply instead of killing the connection."""
    daemon = ServiceDaemon(
        ServingFrontend(small_index, lemmatizer=lemmatizer), max_queue=16
    )
    server = serve_tcp(daemon)
    try:
        out = request_over_tcp(
            server.address, {"query": "who are you who", "top_k": 8, "deadline_ms": 0}
        )
        assert out["partial"] and out["docs"] == []
        err = request_over_tcp(server.address, {"op": "nope"})
        assert "error" in err
    finally:
        server.shutdown()
        server.server_close()
        daemon.stop()
