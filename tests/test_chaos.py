"""Chaos-differential harness for the §14 resilient serving layer.

The headline invariant of DESIGN.md §14, run over the strategies corpora
under seeded fault schedules (the CI ``chaos`` step pins three distinct
seeds): under ANY injected fault sequence — shard crashes and kills,
straggler delays, snapshot bit-flips, arena pressure — every served
response is either

* **exact**: fragment-identical to the SE2.4 oracle over the full corpus
  (``repro.core.oracle``), with every resilience counter zero; or
* **flagged partial**: ``QueryStats.shards_degraded > 0`` / ``partial``,
  fragment-identical to the oracle minus exactly the excluded shards'
  documents, and ranked exactly as ``rank_documents`` over what it covers.

Never silently wrong.  Recovery restores byte-identical shard state
(``index_sets_equal`` vs an uncrashed replica of the snapshot) under a
fresh §12.5 epoch, and the whole schedule replays deterministically from
its seed.
"""

from __future__ import annotations

import pytest

from tests.strategies import make_corpus, make_queries

from repro.core.keys import expand_subqueries, select_keys
from repro.core.oracle import oracle_search
from repro.core.postings import SearchResult
from repro.index import DocumentStore, build_indexes
from repro.index.incremental import index_sets_equal
from repro.runtime.clock import ManualClock
from repro.runtime.fault_tolerance import RestartPolicy
from repro.search.arena import PostingArena
from repro.search.distributed import ShardedSearchService
from repro.search.frontend import SearchRequest, ServingFrontend
from repro.search.relevance import rank_documents
from repro.search.resilience import (
    FaultEvent,
    FaultInjector,
    ResiliencePolicy,
    ShardCrash,
)

# the three fault-schedule seeds the acceptance gate (and CI) replay
CHAOS_SEEDS = (101, 202, 303)
N_SHARDS = 3
CORPUS_SEED = 17
TOP_K = 1000  # >= any corpus size here: responses carry every ranked doc


def _frag_set(results):
    return {(r.doc_id, r.start, r.end) for r in results}


def _response_frags(resp):
    return {(d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments}


def _oracle_union(query, index, lemmatizer):
    union = set()
    for sub in expand_subqueries(query, lemmatizer):
        keys = select_keys(sub, index.fl)
        postings = {k: index.key_postings(k.components) for k in keys}
        union |= _frag_set(oracle_search(sub, keys, postings, index.max_distance))
    return union


def _ranking(frags, top_k=TOP_K):
    results = [SearchResult(doc_id=d, start=s, end=e) for d, s, e in frags]
    return [(doc, score) for doc, score, _ in rank_documents(results, top_k=top_k)]


def _fast_policy(**kw):
    kw.setdefault("restart", RestartPolicy(max_restarts=2, min_backoff_s=0.0))
    kw.setdefault("breaker_cooldown_s", 0.0)
    return ResiliencePolicy(**kw)


def _build_stack(tmp_path, chaos_seed=None, snapshot=True, clock=None, **policy_kw):
    spec = make_corpus(CORPUS_SEED, max_docs=10)
    store = DocumentStore.from_texts(spec.texts)
    full_index = build_indexes(
        store,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    queries = make_queries(CORPUS_SEED, spec, n_queries=5)
    oracles = {q: _oracle_union(q, full_index, store.lemmatizer) for q in queries}
    svc = ShardedSearchService(
        store,
        n_shards=N_SHARDS,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    if snapshot:
        svc.snapshot(tmp_path / "snap")
    injector = (
        FaultInjector.from_seed(chaos_seed, n_shards=N_SHARDS)
        if chaos_seed is not None
        else None
    )
    svc.enable_resilience(policy=_fast_policy(**policy_kw), injector=injector,
                          clock=clock)
    return svc, queries, oracles


def _assert_exact_or_flagged(svc, resp, oracle):
    """The §14 invariant for one response (see module docstring)."""
    got = _response_frags(resp)
    if resp.stats.shards_degraded == 0:
        assert not resp.stats.partial, resp.query
        assert got == oracle, (resp.query, "exact path diverged from oracle")
    else:
        assert resp.stats.partial, resp.query
        dead = svc.supervisor.last_excluded
        expected = {f for f in oracle if f[0] % N_SHARDS not in dead}
        assert got == expected, (resp.query, sorted(dead), "degraded coverage")
        assert [(d.doc_id, d.score) for d in resp.docs] == _ranking(expected), (
            resp.query,
            "degraded ranking is not the exact ranking of the covered set",
        )


# ---------------------------------------------------------------------------
# the headline chaos-differential gate (3 seeds, multiple serving rounds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_chaos_differential_exact_or_flagged(chaos_seed, tmp_path):
    svc, queries, oracles = _build_stack(tmp_path, chaos_seed=chaos_seed)
    saw_fault = False
    # 12 rounds = 12 probe arrivals per shard, past every at_call a seeded
    # schedule can draw (max 9) — the kill event is guaranteed to fire
    for _round in range(12):
        for q, resp in zip(queries, svc.search_batch(queries, top_k=TOP_K)):
            saw_fault = saw_fault or bool(
                resp.stats.shards_degraded
                or resp.stats.retries
                or resp.stats.recoveries
            )
            _assert_exact_or_flagged(svc, resp, oracles[q])
    # the seeded schedules are built to actually exercise the failure path
    assert saw_fault and svc.injector.log, "schedule fired no faults"


@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_chaos_differential_through_frontend(chaos_seed, tmp_path):
    """Same invariant served through the planner/frontend layer: cache hits
    are exact complete responses, misses are exact-or-flagged, and partial
    (degraded/shed) responses are never cached."""
    svc, queries, oracles = _build_stack(tmp_path, chaos_seed=chaos_seed)
    frontend = ServingFrontend(svc)
    for _round in range(4):
        reqs = [SearchRequest(q, top_k=TOP_K) for q in queries]
        for q, resp in zip(queries, frontend.search_many(reqs)):
            if resp.stats.cache_hits:
                # cached => was complete and exact when all shards served
                assert _response_frags(resp) == oracles[q], (q, "stale cache")
            else:
                _assert_exact_or_flagged(svc, resp, oracles[q])


def test_chaos_schedule_replays_deterministically(tmp_path):
    """One seed, two runs: identical fired-event logs, identical responses
    round by round — the property the CI gate depends on."""

    def run(subdir):
        svc, queries, _ = _build_stack(tmp_path / subdir, chaos_seed=CHAOS_SEEDS[0])
        trace = []
        for _round in range(5):
            for resp in svc.search_batch(queries, top_k=TOP_K):
                trace.append(
                    (
                        sorted(_response_frags(resp)),
                        resp.stats.shards_degraded,
                        resp.stats.retries,
                        resp.stats.recoveries,
                    )
                )
        log = [(e["point"], e["kind"], e.get("shard")) for e in svc.injector.log]
        return trace, log

    trace_a, log_a = run("a")
    trace_b, log_b = run("b")
    assert log_a == log_b
    assert trace_a == trace_b


# ---------------------------------------------------------------------------
# recovery: byte-identical state under a fresh §12.5 epoch
# ---------------------------------------------------------------------------


def test_recovery_restores_byte_identical_state(tmp_path):
    svc, queries, oracles = _build_stack(tmp_path)
    # an uncrashed replica of the same snapshot lineage
    replica = ShardedSearchService.restore(tmp_path / "snap")
    victim = 1
    pre_epoch = svc.indexers[victim]._restore_epoch
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=victim, at_call=0),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.recoveries == 1 and resp.stats.shards_degraded == 0
    _assert_exact_or_flagged(svc, resp, oracles[queries[0]])
    eq, why = index_sets_equal(
        svc.indexers[victim].index.to_index_set(),
        replica.indexers[victim].index.to_index_set(),
    )
    assert eq, f"recovered shard != uncrashed replica: {why}"
    # fresh epoch, distinct from the pre-crash boot AND the sibling replica
    assert svc.indexers[victim]._restore_epoch > pre_epoch
    assert (
        svc.indexers[victim]._restore_epoch
        != replica.indexers[victim]._restore_epoch
    )


def test_corrupt_latest_snapshot_falls_back_to_older(tmp_path):
    """A bit-flipped newest snapshot fails the store's CRC verify for real;
    recovery walks back and restores the older snapshot exactly."""
    svc, queries, oracles = _build_stack(tmp_path)
    svc.commit()  # bump generation, then snapshot again -> snap_0 + snap_1
    svc.snapshot(tmp_path / "snap")
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=2, at_call=0),
        FaultEvent("store.load_snapshot", "bitflip", at_call=0, param=0.5),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    kinds = [e["kind"] for e in svc.injector.log]
    assert "bitflip" in kinds, "schedule never corrupted a snapshot"
    assert resp.stats.recoveries == 1 and resp.stats.shards_degraded == 0
    _assert_exact_or_flagged(svc, resp, oracles[queries[0]])


def test_unrecoverable_shard_degrades_gracefully(tmp_path):
    """Every restore candidate corrupt -> the shard stays down and every
    response is flagged with exact coverage of the surviving shards."""
    svc, queries, oracles = _build_stack(tmp_path)
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=0, at_call=0),
        # corrupt EVERY restore attempt, not just the first
        FaultEvent("store.load_snapshot", "bitflip", at_call=0, count=50, param=0.3),
    )
    for _round in range(3):
        for q, resp in zip(queries, svc.search_batch(queries, top_k=TOP_K)):
            assert resp.stats.shards_degraded == 1 and resp.stats.partial
            assert resp.stats.recoveries == 0
            _assert_exact_or_flagged(svc, resp, oracles[q])
    assert svc.supervisor.recoveries == 0
    assert svc.supervisor.health.errors[0] > 0


# ---------------------------------------------------------------------------
# transient faults: retries, hedging, arena pressure
# ---------------------------------------------------------------------------


def test_transient_crash_retries_then_serves_exact(tmp_path):
    svc, queries, oracles = _build_stack(tmp_path)
    svc.injector.schedule = (
        FaultEvent("shard.search", "crash", shard=1, at_call=0, count=1),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.retries == 1 and resp.stats.shards_degraded == 0
    assert not resp.stats.partial
    assert _response_frags(resp) == oracles[queries[0]]


def test_straggler_hedge_keeps_shard_and_exactness(tmp_path):
    """Hedge decision on a virtual clock (§16.4): the injected 0.2 s
    straggler delay advances virtual time past the 0.02 s hedge threshold
    — no real sleep, no thread race — and the whole run costs EXACTLY the
    injected delay, assertable as a tick boundary."""
    clock = ManualClock()
    svc, queries, oracles = _build_stack(
        tmp_path, snapshot=False, hedge_after_s=0.02, clock=clock
    )
    svc.injector.schedule = (
        FaultEvent("shard.straggler", "delay", shard=2, at_call=0, delay_s=0.2),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.hedges == 1 and resp.stats.shards_degraded == 0
    assert _response_frags(resp) == oracles[queries[0]]
    # exact tick boundary: the ONLY time that passed in the entire serving
    # round is the one injected straggler delay
    assert clock.peek() == 0.2
    # the slow probe still landed in the latency window for MAD detection
    assert svc.supervisor.health.probes > 0


def test_straggler_below_hedge_threshold_never_hedges(tmp_path):
    """The complementary tick boundary: a delay UNDER the hedge threshold
    must not fire the hedge, and virtual time advances by exactly that
    delay (§16.4 determinism — the decision is an exact comparison, not a
    thread race)."""
    clock = ManualClock()
    svc, queries, oracles = _build_stack(
        tmp_path, snapshot=False, hedge_after_s=0.02, clock=clock
    )
    svc.injector.schedule = (
        FaultEvent("shard.straggler", "delay", shard=2, at_call=0, delay_s=0.01),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.hedges == 0 and resp.stats.shards_degraded == 0
    assert _response_frags(resp) == oracles[queries[0]]
    assert clock.peek() == 0.01


def test_arena_pressure_falls_back_to_host_exactly(tmp_path):
    spec = make_corpus(CORPUS_SEED, max_docs=10)
    store = DocumentStore.from_texts(spec.texts)
    kw = dict(
        n_shards=N_SHARDS,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    baseline = ShardedSearchService(store, **kw)
    queries = make_queries(CORPUS_SEED, spec, n_queries=3)
    want = [_response_frags(r) for r in baseline.search_batch(queries, top_k=TOP_K)]

    arena = PostingArena(budget_bytes=32 << 20)
    svc = ShardedSearchService(store, arena=arena, **kw)
    svc.enable_resilience(
        policy=_fast_policy(),
        injector=FaultInjector(
            schedule=[FaultEvent("arena.acquire", "overflow", at_call=0, count=1)]
        ),
    )
    # round 1 under injected pressure (host fallback), round 2 resident
    for _round in range(2):
        got = [_response_frags(r) for r in svc.search_batch(queries, top_k=TOP_K)]
        assert got == want, "arena pressure changed fragments"
    assert arena.pressure_events == 1


# ---------------------------------------------------------------------------
# fault-free traffic: every resilience counter stays zero
# ---------------------------------------------------------------------------


def test_fault_free_traffic_leaves_counters_zero(tmp_path):
    svc, queries, oracles = _build_stack(tmp_path)  # empty schedule
    frontend = ServingFrontend(svc, max_inflight=None)
    for _round in range(2):
        for resp in svc.search_batch(queries, top_k=TOP_K):
            st = resp.stats
            assert (
                st.retries,
                st.hedges,
                st.shards_degraded,
                st.recoveries,
                st.shed,
            ) == (0, 0, 0, 0, 0)
            assert not st.partial
        for resp in frontend.search_many(queries):
            st = resp.stats
            assert (
                st.retries,
                st.hedges,
                st.shards_degraded,
                st.recoveries,
                st.shed,
            ) == (0, 0, 0, 0, 0)
    m = frontend.metrics()
    assert m["sheds"] == 0
    assert m["resilience"]["recoveries"] == 0
    assert m["resilience"]["fired"] == 0
    assert all(s == "closed" for s in m["resilience"]["breaker_states"])


def test_load_shedding_is_flagged_and_exactly_ranked(tmp_path):
    """Overflow misses shed to the admission budget: flagged via
    ``QueryStats.shed``, partial when work was dropped, and what they do
    return ranks exactly (the PR 3 partial contract)."""
    svc, queries, oracles = _build_stack(tmp_path)
    frontend = ServingFrontend(svc, max_inflight=1, shed_deadline_sec=0.0)
    # duplicates coalesce instead of missing, so shed over unique queries
    unique = list(dict.fromkeys(queries))
    assert len(unique) >= 2, "corpus seed produced a single unique query"
    reqs = [SearchRequest(q, top_k=TOP_K) for q in unique]
    out = frontend.search_many(reqs)
    assert [r.stats.shed for r in out] == [0] + [1] * (len(unique) - 1)
    for q, resp in zip(unique[1:], out[1:]):
        assert resp.stats.cache_hits == 0
        assert resp.docs == []  # zero budget admits nothing: empty partial
        if oracles[q]:
            # real work was dropped -> must be flagged partial; a query
            # with nothing executable sheds to an exact empty response
            assert resp.stats.partial
    # the unshedded request is exact
    assert _response_frags(out[0]) == oracles[unique[0]]
    assert frontend.metrics()["sheds"] == len(unique) - 1
    # shed PARTIAL responses (real work dropped) were not cached: a
    # re-serve misses again — and, no longer over the inflight cap, now
    # executes fully and returns the exact result
    dropped = [
        (i, q) for i, q in enumerate(unique) if i > 0 and oracles[q]
    ]
    if dropped:
        i, q = dropped[0]
        again = frontend.search_many([reqs[i]])[0]
        assert again.stats.cache_hits == 0 and again.stats.shed == 0
        assert _response_frags(again) == oracles[q]


def test_legacy_dead_shards_routes_through_injector(tmp_path):
    """The ``dead_shards=`` argument is the same failure path as detection:
    held shards fail probes, responses are flagged and exactly ranked, and
    the hold is scoped to the call (the next call serves all shards)."""
    svc, queries, oracles = _build_stack(tmp_path, snapshot=False)
    q = queries[0]
    resp = svc.search_batch([q], top_k=TOP_K, dead_shards=(1,))[0]
    assert resp.stats.shards_degraded == 1 and resp.stats.partial
    assert svc.supervisor.last_excluded == frozenset({1})
    _assert_exact_or_flagged(svc, resp, oracles[q])
    assert not svc.injector.is_down(1), "hold must not outlive the call"
    clean = svc.search_batch([q], top_k=TOP_K)[0]
    assert clean.stats.shards_degraded == 0
    assert _response_frags(clean) == oracles[q]
