"""Chaos-differential harness for the §14 resilient serving layer.

The headline invariant of DESIGN.md §14, run over the strategies corpora
under seeded fault schedules (the CI ``chaos`` step pins three distinct
seeds): under ANY injected fault sequence — shard crashes and kills,
straggler delays, snapshot bit-flips, arena pressure — every served
response is either

* **exact**: fragment-identical to the SE2.4 oracle over the full corpus
  (``repro.core.oracle``), with every resilience counter zero; or
* **flagged partial**: ``QueryStats.shards_degraded > 0`` / ``partial``,
  fragment-identical to the oracle minus exactly the excluded shards'
  documents, and ranked exactly as ``rank_documents`` over what it covers.

Never silently wrong.  Recovery restores byte-identical shard state
(``index_sets_equal`` vs an uncrashed replica of the snapshot) under a
fresh §12.5 epoch, and the whole schedule replays deterministically from
its seed.
"""

from __future__ import annotations

import pytest

from tests.strategies import make_corpus, make_queries

from repro.core.keys import expand_subqueries, select_keys
from repro.core.oracle import oracle_search
from repro.core.postings import SearchResult
from repro.index import DocumentStore, IncrementalIndexer, build_indexes
from repro.index.incremental import index_sets_equal
from repro.runtime.clock import ManualClock
from repro.runtime.fault_tolerance import RestartPolicy
from repro.search.arena import PostingArena
from repro.search.distributed import ShardedSearchService
from repro.search.frontend import SearchRequest, ServingFrontend
from repro.search.relevance import rank_documents
from repro.search.resilience import (
    FaultEvent,
    FaultInjector,
    ResiliencePolicy,
    ShardCrash,
)
from repro.search.service import (
    ReplicatedServiceDaemon,
    ServiceDaemon,
    response_to_wire,
)

# the three fault-schedule seeds the acceptance gate (and CI) replay
CHAOS_SEEDS = (101, 202, 303)
N_SHARDS = 3
CORPUS_SEED = 17
TOP_K = 1000  # >= any corpus size here: responses carry every ranked doc


def _frag_set(results):
    return {(r.doc_id, r.start, r.end) for r in results}


def _response_frags(resp):
    return {(d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments}


def _oracle_union(query, index, lemmatizer):
    union = set()
    for sub in expand_subqueries(query, lemmatizer):
        keys = select_keys(sub, index.fl)
        postings = {k: index.key_postings(k.components) for k in keys}
        union |= _frag_set(oracle_search(sub, keys, postings, index.max_distance))
    return union


def _ranking(frags, top_k=TOP_K):
    results = [SearchResult(doc_id=d, start=s, end=e) for d, s, e in frags]
    return [(doc, score) for doc, score, _ in rank_documents(results, top_k=top_k)]


def _fast_policy(**kw):
    kw.setdefault("restart", RestartPolicy(max_restarts=2, min_backoff_s=0.0))
    kw.setdefault("breaker_cooldown_s", 0.0)
    return ResiliencePolicy(**kw)


def _build_stack(tmp_path, chaos_seed=None, snapshot=True, clock=None, **policy_kw):
    spec = make_corpus(CORPUS_SEED, max_docs=10)
    store = DocumentStore.from_texts(spec.texts)
    full_index = build_indexes(
        store,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    queries = make_queries(CORPUS_SEED, spec, n_queries=5)
    oracles = {q: _oracle_union(q, full_index, store.lemmatizer) for q in queries}
    svc = ShardedSearchService(
        store,
        n_shards=N_SHARDS,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    if snapshot:
        svc.snapshot(tmp_path / "snap")
    injector = (
        FaultInjector.from_seed(chaos_seed, n_shards=N_SHARDS)
        if chaos_seed is not None
        else None
    )
    svc.enable_resilience(policy=_fast_policy(**policy_kw), injector=injector,
                          clock=clock)
    return svc, queries, oracles


def _assert_exact_or_flagged(svc, resp, oracle):
    """The §14 invariant for one response (see module docstring)."""
    got = _response_frags(resp)
    if resp.stats.shards_degraded == 0:
        assert not resp.stats.partial, resp.query
        assert got == oracle, (resp.query, "exact path diverged from oracle")
    else:
        assert resp.stats.partial, resp.query
        dead = svc.supervisor.last_excluded
        expected = {f for f in oracle if f[0] % N_SHARDS not in dead}
        assert got == expected, (resp.query, sorted(dead), "degraded coverage")
        assert [(d.doc_id, d.score) for d in resp.docs] == _ranking(expected), (
            resp.query,
            "degraded ranking is not the exact ranking of the covered set",
        )


# ---------------------------------------------------------------------------
# the headline chaos-differential gate (3 seeds, multiple serving rounds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_chaos_differential_exact_or_flagged(chaos_seed, tmp_path):
    svc, queries, oracles = _build_stack(tmp_path, chaos_seed=chaos_seed)
    saw_fault = False
    # 12 rounds = 12 probe arrivals per shard, past every at_call a seeded
    # schedule can draw (max 9) — the kill event is guaranteed to fire
    for _round in range(12):
        for q, resp in zip(queries, svc.search_batch(queries, top_k=TOP_K)):
            saw_fault = saw_fault or bool(
                resp.stats.shards_degraded
                or resp.stats.retries
                or resp.stats.recoveries
            )
            _assert_exact_or_flagged(svc, resp, oracles[q])
    # the seeded schedules are built to actually exercise the failure path
    assert saw_fault and svc.injector.log, "schedule fired no faults"


@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_chaos_differential_through_frontend(chaos_seed, tmp_path):
    """Same invariant served through the planner/frontend layer: cache hits
    are exact complete responses, misses are exact-or-flagged, and partial
    (degraded/shed) responses are never cached."""
    svc, queries, oracles = _build_stack(tmp_path, chaos_seed=chaos_seed)
    frontend = ServingFrontend(svc)
    for _round in range(4):
        reqs = [SearchRequest(q, top_k=TOP_K) for q in queries]
        for q, resp in zip(queries, frontend.search_many(reqs)):
            if resp.stats.cache_hits:
                # cached => was complete and exact when all shards served
                assert _response_frags(resp) == oracles[q], (q, "stale cache")
            else:
                _assert_exact_or_flagged(svc, resp, oracles[q])


def test_chaos_schedule_replays_deterministically(tmp_path):
    """One seed, two runs: identical fired-event logs, identical responses
    round by round — the property the CI gate depends on."""

    def run(subdir):
        svc, queries, _ = _build_stack(tmp_path / subdir, chaos_seed=CHAOS_SEEDS[0])
        trace = []
        for _round in range(5):
            for resp in svc.search_batch(queries, top_k=TOP_K):
                trace.append(
                    (
                        sorted(_response_frags(resp)),
                        resp.stats.shards_degraded,
                        resp.stats.retries,
                        resp.stats.recoveries,
                    )
                )
        log = [(e["point"], e["kind"], e.get("shard")) for e in svc.injector.log]
        return trace, log

    trace_a, log_a = run("a")
    trace_b, log_b = run("b")
    assert log_a == log_b
    assert trace_a == trace_b


# ---------------------------------------------------------------------------
# recovery: byte-identical state under a fresh §12.5 epoch
# ---------------------------------------------------------------------------


def test_recovery_restores_byte_identical_state(tmp_path):
    svc, queries, oracles = _build_stack(tmp_path)
    # an uncrashed replica of the same snapshot lineage
    replica = ShardedSearchService.restore(tmp_path / "snap")
    victim = 1
    pre_epoch = svc.indexers[victim]._restore_epoch
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=victim, at_call=0),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.recoveries == 1 and resp.stats.shards_degraded == 0
    _assert_exact_or_flagged(svc, resp, oracles[queries[0]])
    eq, why = index_sets_equal(
        svc.indexers[victim].index.to_index_set(),
        replica.indexers[victim].index.to_index_set(),
    )
    assert eq, f"recovered shard != uncrashed replica: {why}"
    # fresh epoch, distinct from the pre-crash boot AND the sibling replica
    assert svc.indexers[victim]._restore_epoch > pre_epoch
    assert (
        svc.indexers[victim]._restore_epoch
        != replica.indexers[victim]._restore_epoch
    )


def test_corrupt_latest_snapshot_falls_back_to_older(tmp_path):
    """A bit-flipped newest snapshot fails the store's CRC verify for real;
    recovery walks back and restores the older snapshot exactly."""
    svc, queries, oracles = _build_stack(tmp_path)
    svc.commit()  # bump generation, then snapshot again -> snap_0 + snap_1
    svc.snapshot(tmp_path / "snap")
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=2, at_call=0),
        FaultEvent("store.load_snapshot", "bitflip", at_call=0, param=0.5),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    kinds = [e["kind"] for e in svc.injector.log]
    assert "bitflip" in kinds, "schedule never corrupted a snapshot"
    assert resp.stats.recoveries == 1 and resp.stats.shards_degraded == 0
    _assert_exact_or_flagged(svc, resp, oracles[queries[0]])


def test_unrecoverable_shard_degrades_gracefully(tmp_path):
    """Every restore candidate corrupt -> the shard stays down and every
    response is flagged with exact coverage of the surviving shards."""
    svc, queries, oracles = _build_stack(tmp_path)
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=0, at_call=0),
        # corrupt EVERY restore attempt, not just the first
        FaultEvent("store.load_snapshot", "bitflip", at_call=0, count=50, param=0.3),
    )
    for _round in range(3):
        for q, resp in zip(queries, svc.search_batch(queries, top_k=TOP_K)):
            assert resp.stats.shards_degraded == 1 and resp.stats.partial
            assert resp.stats.recoveries == 0
            _assert_exact_or_flagged(svc, resp, oracles[q])
    assert svc.supervisor.recoveries == 0
    assert svc.supervisor.health.errors[0] > 0


# ---------------------------------------------------------------------------
# transient faults: retries, hedging, arena pressure
# ---------------------------------------------------------------------------


def test_transient_crash_retries_then_serves_exact(tmp_path):
    svc, queries, oracles = _build_stack(tmp_path)
    svc.injector.schedule = (
        FaultEvent("shard.search", "crash", shard=1, at_call=0, count=1),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.retries == 1 and resp.stats.shards_degraded == 0
    assert not resp.stats.partial
    assert _response_frags(resp) == oracles[queries[0]]


def test_straggler_hedge_keeps_shard_and_exactness(tmp_path):
    """Hedge decision on a virtual clock (§16.4): the injected 0.2 s
    straggler delay advances virtual time past the 0.02 s hedge threshold
    — no real sleep, no thread race — and the whole run costs EXACTLY the
    injected delay, assertable as a tick boundary."""
    clock = ManualClock()
    svc, queries, oracles = _build_stack(
        tmp_path, snapshot=False, hedge_after_s=0.02, clock=clock
    )
    svc.injector.schedule = (
        FaultEvent("shard.straggler", "delay", shard=2, at_call=0, delay_s=0.2),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.hedges == 1 and resp.stats.shards_degraded == 0
    assert _response_frags(resp) == oracles[queries[0]]
    # exact tick boundary: the ONLY time that passed in the entire serving
    # round is the one injected straggler delay
    assert clock.peek() == 0.2
    # the slow probe still landed in the latency window for MAD detection
    assert svc.supervisor.health.probes > 0


def test_straggler_below_hedge_threshold_never_hedges(tmp_path):
    """The complementary tick boundary: a delay UNDER the hedge threshold
    must not fire the hedge, and virtual time advances by exactly that
    delay (§16.4 determinism — the decision is an exact comparison, not a
    thread race)."""
    clock = ManualClock()
    svc, queries, oracles = _build_stack(
        tmp_path, snapshot=False, hedge_after_s=0.02, clock=clock
    )
    svc.injector.schedule = (
        FaultEvent("shard.straggler", "delay", shard=2, at_call=0, delay_s=0.01),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.hedges == 0 and resp.stats.shards_degraded == 0
    assert _response_frags(resp) == oracles[queries[0]]
    assert clock.peek() == 0.01


def test_arena_pressure_falls_back_to_host_exactly(tmp_path):
    spec = make_corpus(CORPUS_SEED, max_docs=10)
    store = DocumentStore.from_texts(spec.texts)
    kw = dict(
        n_shards=N_SHARDS,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    baseline = ShardedSearchService(store, **kw)
    queries = make_queries(CORPUS_SEED, spec, n_queries=3)
    want = [_response_frags(r) for r in baseline.search_batch(queries, top_k=TOP_K)]

    arena = PostingArena(budget_bytes=32 << 20)
    svc = ShardedSearchService(store, arena=arena, **kw)
    svc.enable_resilience(
        policy=_fast_policy(),
        injector=FaultInjector(
            schedule=[FaultEvent("arena.acquire", "overflow", at_call=0, count=1)]
        ),
    )
    # round 1 under injected pressure (host fallback), round 2 resident
    for _round in range(2):
        got = [_response_frags(r) for r in svc.search_batch(queries, top_k=TOP_K)]
        assert got == want, "arena pressure changed fragments"
    assert arena.pressure_events == 1


# ---------------------------------------------------------------------------
# fault-free traffic: every resilience counter stays zero
# ---------------------------------------------------------------------------


def test_fault_free_traffic_leaves_counters_zero(tmp_path):
    svc, queries, oracles = _build_stack(tmp_path)  # empty schedule
    frontend = ServingFrontend(svc, max_inflight=None)
    for _round in range(2):
        for resp in svc.search_batch(queries, top_k=TOP_K):
            st = resp.stats
            assert (
                st.retries,
                st.hedges,
                st.shards_degraded,
                st.recoveries,
                st.shed,
            ) == (0, 0, 0, 0, 0)
            assert not st.partial
        for resp in frontend.search_many(queries):
            st = resp.stats
            assert (
                st.retries,
                st.hedges,
                st.shards_degraded,
                st.recoveries,
                st.shed,
            ) == (0, 0, 0, 0, 0)
    m = frontend.metrics()
    assert m["sheds"] == 0
    assert m["resilience"]["recoveries"] == 0
    assert m["resilience"]["fired"] == 0
    assert all(s == "closed" for s in m["resilience"]["breaker_states"])


def test_load_shedding_is_flagged_and_exactly_ranked(tmp_path):
    """Overflow misses shed to the admission budget: flagged via
    ``QueryStats.shed``, partial when work was dropped, and what they do
    return ranks exactly (the PR 3 partial contract)."""
    svc, queries, oracles = _build_stack(tmp_path)
    frontend = ServingFrontend(svc, max_inflight=1, shed_deadline_sec=0.0)
    # duplicates coalesce instead of missing, so shed over unique queries
    unique = list(dict.fromkeys(queries))
    assert len(unique) >= 2, "corpus seed produced a single unique query"
    reqs = [SearchRequest(q, top_k=TOP_K) for q in unique]
    out = frontend.search_many(reqs)
    assert [r.stats.shed for r in out] == [0] + [1] * (len(unique) - 1)
    for q, resp in zip(unique[1:], out[1:]):
        assert resp.stats.cache_hits == 0
        assert resp.docs == []  # zero budget admits nothing: empty partial
        if oracles[q]:
            # real work was dropped -> must be flagged partial; a query
            # with nothing executable sheds to an exact empty response
            assert resp.stats.partial
    # the unshedded request is exact
    assert _response_frags(out[0]) == oracles[unique[0]]
    assert frontend.metrics()["sheds"] == len(unique) - 1
    # shed PARTIAL responses (real work dropped) were not cached: a
    # re-serve misses again — and, no longer over the inflight cap, now
    # executes fully and returns the exact result
    dropped = [
        (i, q) for i, q in enumerate(unique) if i > 0 and oracles[q]
    ]
    if dropped:
        i, q = dropped[0]
        again = frontend.search_many([reqs[i]])[0]
        assert again.stats.cache_hits == 0 and again.stats.shed == 0
        assert _response_frags(again) == oracles[q]


def test_legacy_dead_shards_routes_through_injector(tmp_path):
    """The ``dead_shards=`` argument is the same failure path as detection:
    held shards fail probes, responses are flagged and exactly ranked, and
    the hold is scoped to the call (the next call serves all shards)."""
    svc, queries, oracles = _build_stack(tmp_path, snapshot=False)
    q = queries[0]
    resp = svc.search_batch([q], top_k=TOP_K, dead_shards=(1,))[0]
    assert resp.stats.shards_degraded == 1 and resp.stats.partial
    assert svc.supervisor.last_excluded == frozenset({1})
    _assert_exact_or_flagged(svc, resp, oracles[q])
    assert not svc.injector.is_down(1), "hold must not outlive the call"
    clean = svc.search_batch([q], top_k=TOP_K)[0]
    assert clean.stats.shards_degraded == 0
    assert _response_frags(clean) == oracles[q]

# ---------------------------------------------------------------------------
# §18: WAL zero-data-loss recovery
# ---------------------------------------------------------------------------

# the CI chaos matrix replays the base seeds PLUS two wal-fault seeds
WAL_SEEDS = CHAOS_SEEDS + (404, 505)


def _build_wal_stack(tmp_path, chaos_seed=None, wal_faults=False, **policy_kw):
    """A WAL-attached chaos stack: snapshot anchored by a §18.2 checkpoint,
    then (optionally) a seeded schedule extended with ``wal.*`` /
    ``daemon.crash`` events (``FaultInjector.from_seed(..., wal=True)``)."""
    spec = make_corpus(CORPUS_SEED, max_docs=10)
    store = DocumentStore.from_texts(spec.texts)
    queries = make_queries(CORPUS_SEED, spec, n_queries=5)
    svc = ShardedSearchService(
        store,
        n_shards=N_SHARDS,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    svc.enable_wal(tmp_path / "snap")
    svc.snapshot(tmp_path / "snap")  # clean anchored baseline snapshot
    injector = (
        FaultInjector.from_seed(chaos_seed, n_shards=N_SHARDS, wal=wal_faults)
        if chaos_seed is not None
        else None
    )
    svc.enable_resilience(policy=_fast_policy(**policy_kw), injector=injector)
    # re-arm the WALs with the (possibly empty) injector so the §14
    # ``wal.append`` / ``wal.torn_tail`` fault points fire per shard
    svc.enable_wal(tmp_path / "snap")
    return svc, store, queries


def _shard_lineage(tmp_path, shard):
    return tmp_path / "snap" / f"shard_{shard:02d}"


def _restore_lineage(path, lemmatizer):
    """Restore a shard lineage the way recovery does (§12.4 + §18.2): the
    newest snapshot whose CRCs verify, plus its WAL replay tail.  A
    snapshot physically corrupted by an injected bitflip fails loudly and
    the next-older one is tried — never silently wrong bytes."""
    from repro.index.store import StoreError

    ids = sorted(
        int(p.name.rsplit("_", 1)[1])
        for p in path.glob("snap_*")
        if p.is_dir() and p.name.rsplit("_", 1)[1].isdigit()
    )
    last_err = None
    for sid in reversed(ids):
        try:
            return IncrementalIndexer.restore(
                path, snapshot_id=sid, lemmatizer=lemmatizer
            )
        except StoreError as e:
            last_err = e
    raise last_err if last_err else FileNotFoundError(path)


def _assert_durable_equals_live(svc, store, tmp_path, ctx=""):
    """The §18.2 zero-data-loss invariant, checked per shard: a FRESH
    restore of the durable lineage (snapshot + WAL-tail replay) is
    ``index_sets_equal`` to the live in-memory shard — every acknowledged
    op is durable, every unacknowledged one left no phantom."""
    for i, live in enumerate(svc.indexers):
        replica = _restore_lineage(_shard_lineage(tmp_path, i), store.lemmatizer)
        eq, why = index_sets_equal(
            live.index.to_index_set(), replica.index.to_index_set()
        )
        assert eq, f"{ctx}: shard {i} durable state != live: {why}"
        assert live.documents.keys() == replica.documents.keys(), (ctx, i)
        assert live.tombstones == replica.tombstones, (ctx, i)
        assert sorted(live._buffer) == sorted(replica._buffer), (
            ctx, i, "buffered (acked, uncommitted) adds diverged",
        )


def test_wal_recovery_restores_post_snapshot_commits(tmp_path):
    """A killed shard comes back ``index_sets_equal`` to its durable
    lineage INCLUDING commits after the last snapshot — the §18 tentpole
    (the §12 snapshot alone would lose them)."""
    svc, store, queries = _build_wal_stack(tmp_path)
    oracles = {}  # corpus mutates below: state equality is the invariant
    svc.add_documents(["zeta omega gamma delta epsilon"])
    svc.commit()  # acked post-snapshot write on every shard (FL reduce)
    victim = 1
    pre_epoch = svc.indexers[victim]._restore_epoch
    want_docs = set(svc.indexers[victim].documents)
    svc.injector.schedule = (
        FaultEvent("shard.search", "kill", shard=victim, at_call=0),
    )
    resp = svc.search_batch(queries[:1], top_k=TOP_K)[0]
    assert resp.stats.recoveries == 1 and resp.stats.shards_degraded == 0
    # the replay actually carried records (at least the logged commit)
    assert svc.supervisor.wal_records_replayed > 0
    assert svc.supervisor.metrics()["wal_records_replayed"] > 0
    # recovered == durable lineage == pre-crash live state
    assert set(svc.indexers[victim].documents) == want_docs
    _assert_durable_equals_live(svc, store, tmp_path, "post-recovery")
    # fresh §12.5 epoch on the recovered boot
    assert svc.indexers[victim]._restore_epoch > pre_epoch
    del oracles


def test_crash_mid_commit_loses_nothing_acked(tmp_path):
    """``wal.torn_tail`` tears a commit mid-frame: the op was never
    acknowledged, the live shard never mutated, and recovery truncates the
    torn bytes — durable state stays exactly the acknowledged prefix."""
    svc, store, queries = _build_wal_stack(tmp_path)
    svc.add_documents(["first acked doc alpha beta"])
    svc.commit()  # fully acknowledged round
    victim = 0
    svc.injector.schedule = (
        FaultEvent("wal.torn_tail", "kill", shard=victim, at_call=0),
    )
    svc.injector._arrivals.clear()  # at_call counts from the NEXT append
    before = set(svc.indexers[victim].documents)
    with pytest.raises(ShardCrash):
        svc.commit()  # victim's WAL append tears mid-frame
    assert set(svc.indexers[victim].documents) == before
    # injected torn frame really hit the disk, reader truncates it
    fired = [e for e in svc.injector.log if e["point"] == "wal.torn_tail"]
    assert fired, "torn-tail event never fired"
    _assert_durable_equals_live(svc, store, tmp_path, "after torn commit")


def test_wal_append_crash_aborts_before_any_mutation(tmp_path):
    """``wal.append`` crash: the op is lost BUT was never acknowledged and
    never half-applied — no frame on disk, no live mutation, and the
    durable lineage still matches the live state exactly."""
    svc, store, queries = _build_wal_stack(tmp_path)
    victim = 2
    svc.injector.schedule = (
        FaultEvent("wal.append", "crash", shard=victim, at_call=0, count=1),
    )
    n_records = len(svc.indexers[victim].wal.records())
    with pytest.raises(ShardCrash):
        svc.commit()
    assert len(svc.indexers[victim].wal.records()) == n_records
    _assert_durable_equals_live(svc, store, tmp_path, "after aborted append")
    # the transient fault passed: the SAME op re-issued now succeeds and
    # both live and durable state advance together
    svc.commit()
    assert len(svc.indexers[victim].wal.records()) == n_records + 1
    _assert_durable_equals_live(svc, store, tmp_path, "after retried commit")


@pytest.mark.parametrize("chaos_seed", WAL_SEEDS)
def test_wal_chaos_differential_durable_equals_live(chaos_seed, tmp_path):
    """Seeded §18 chaos differential (the CI matrix step): rounds of
    mutations + serving under ``wal.append`` / ``wal.torn_tail`` / shard
    kills.  Crashed mutations are unacknowledged no-ops; after every round
    the durable lineage of EVERY shard replays to exactly the live state
    (zero data loss, no phantoms), and recovered shards carry replayed
    records."""
    svc, store, queries = _build_wal_stack(
        tmp_path, chaos_seed=chaos_seed, wal_faults=True
    )
    for rnd in range(4):
        try:
            svc.add_documents([f"round {rnd} mutation doc alpha beta gamma"])
            svc.commit()
        except ShardCrash:
            pass  # aborted before the crashed shard mutated (unacked)
        try:
            svc.snapshot(tmp_path / "snap")  # checkpoint under fire
        except ShardCrash:
            pass
        svc.search_batch(queries, top_k=TOP_K)  # drives shard faults+recovery
        _assert_durable_equals_live(
            svc, store, tmp_path, f"seed {chaos_seed} round {rnd}"
        )
    wal_fired = [e for e in svc.injector.log if e["point"].startswith("wal.")]
    assert wal_fired, "wal=True schedule fired no wal faults"


# ---------------------------------------------------------------------------
# §18.3: replicated daemon failover (virtual clock, no real sleeps)
# ---------------------------------------------------------------------------


def _serving_stack():
    spec = make_corpus(CORPUS_SEED, max_docs=8)
    store = DocumentStore.from_texts(spec.texts)
    svc = ShardedSearchService(
        store,
        n_shards=N_SHARDS,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
        algorithm="fused",
        incremental=True,
    )
    queries = list(dict.fromkeys(make_queries(CORPUS_SEED, spec, n_queries=6)))
    return svc, queries


def _replicated(svc, n=2, clock=None, injector=None, lease_sec=0.05):
    clock = clock or ManualClock()
    return (
        ReplicatedServiceDaemon(
            [ServiceDaemon([ServingFrontend(svc)], clock=clock) for _ in range(n)],
            clock=clock,
            lease_sec=lease_sec,
            injector=injector,
        ),
        clock,
    )


def _wire(resp):
    return response_to_wire(resp)  # no ticket: content + flags only


def test_replicated_failover_readmits_exactly_once_byte_identical():
    """Kill the primary with every request in flight: after the lease the
    successor re-admits each unanswered ticket EXACTLY once under its
    original id, and responses are byte-identical to a fault-free serve."""
    svc, queries = _serving_stack()
    ref_frontend = ServingFrontend(svc)
    want = [
        _wire(r)
        for r in ref_frontend.search_many(
            [SearchRequest(q, top_k=TOP_K) for q in queries]
        )
    ]
    rep, clock = _replicated(svc, n=2)
    handles = [
        rep.submit(SearchRequest(q, top_k=TOP_K), request_id=f"req-{i}")
        for i, q in enumerate(queries)
    ]
    assert rep.crash_primary() == 0  # everything still queued on replica 0
    rep.drain()  # advances the virtual clock past the lease, then re-admits
    m = rep.metrics()
    assert m["failovers"] == 1 and m["primary"] == 1
    assert m["readmitted"] == len(handles)
    assert [h.readmissions for h in handles] == [1] * len(handles)
    assert [_wire(h.result()) for h in handles] == want
    # exactly once: every id completed once, none shed, none duplicated
    assert m["completed"] == len(handles) and m["requests"] == len(handles)


def test_replicated_lease_window_parks_then_serves_exactly():
    """Requests arriving while the dead primary still holds the lease are
    parked (never shed while a live replica remains) and admitted to the
    successor at failover as FIRST admissions, not re-admissions."""
    svc, queries = _serving_stack()
    ref = _wire(
        ServingFrontend(svc).search_many([SearchRequest(queries[0], top_k=TOP_K)])[0]
    )
    rep, clock = _replicated(svc, n=2)
    assert rep.crash_primary() == 0
    h = rep.submit(SearchRequest(queries[0], top_k=TOP_K), request_id="parked")
    assert not h.done(), "lease window must park, not shed"
    rep.drain()
    m = rep.metrics()
    assert m["failovers"] == 1 and m["readmitted"] == 0
    assert h.readmissions == 0
    assert _wire(h.result()) == ref


def test_replicated_dedup_returns_recorded_response_verbatim():
    svc, queries = _serving_stack()
    rep, clock = _replicated(svc, n=2)
    h1 = rep.submit(SearchRequest(queries[0], top_k=TOP_K), request_id="dup")
    rep.drain()
    first = h1.result()
    h2 = rep.submit(SearchRequest(queries[0], top_k=TOP_K), request_id="dup")
    assert h2 is h1  # the registry IS the idempotency store
    assert h2.result() is first  # recorded response, no recomputation
    assert rep.metrics()["dedup_hits"] == 1


def test_replicated_daemon_crash_fault_point_and_down_set_isolation():
    """The ``daemon.crash`` §14 fault point kills the primary mid-pump via
    the injector — and must NOT mark any index shard down (replica ids are
    not shard ids)."""
    svc, queries = _serving_stack()
    ref_frontend = ServingFrontend(svc)
    want = [
        _wire(r)
        for r in ref_frontend.search_many(
            [SearchRequest(q, top_k=TOP_K) for q in queries[:3]]
        )
    ]
    injector = FaultInjector(
        schedule=[FaultEvent("daemon.crash", "kill", shard=0, at_call=0)]
    )
    rep, clock = _replicated(svc, n=3, injector=injector)
    handles = [
        rep.submit(SearchRequest(q, top_k=TOP_K), request_id=f"r{i}")
        for i, q in enumerate(queries[:3])
    ]
    rep.drain()
    assert [e["point"] for e in injector.log] == ["daemon.crash"]
    assert not injector.down, "daemon replica kill leaked into the shard down-set"
    m = rep.metrics()
    assert m["failovers"] == 1 and m["alive"] == [False, True, True]
    assert [_wire(h.result()) for h in handles] == want


def test_replicated_all_dead_sheds_flagged_never_errors():
    svc, queries = _serving_stack()
    rep, clock = _replicated(svc, n=1)
    assert rep.crash_primary() == 0
    h = rep.submit(SearchRequest(queries[0], top_k=TOP_K), request_id="doomed")
    assert h.done()  # nobody can ever serve it: flagged shed immediately
    resp = h.result()
    assert resp.stats.shed == 1 and resp.stats.partial
    assert resp.docs == []
    assert rep.metrics()["primary"] is None
