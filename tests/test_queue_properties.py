"""Property-based queue tests for the §16 continuous-batching daemon.

Random arrival schedules (``tests/strategies.make_arrival_schedule``: QPS
bursts, mixed deadline populations) replayed on a virtual clock, asserting
the DESIGN.md §16.2 invariants:

* **admission order** — batches are formed FIFO from consecutive tickets,
  retire FIFO, and no ticket is ever starved or lost
  (``submitted == completed + shed_queue`` conservation);
* **byte-identity** — a single-replica daemon's responses are identical
  (docs, scores, fragments, flags) to a serial
  ``ServingFrontend.search_many`` run over the same slates with the same
  effective deadlines;
* **shed/partial flagging** — queue-overflow sheds are flagged
  (``stats.shed`` / ``partial``), empty, and never cached: re-serving the
  same query under no pressure returns the full exact result;
* **continuous batching** — arrivals during an in-flight batch form the
  next batch (mean occupancy > 1 on a saturating schedule).

Runs under real hypothesis or the fixed-seed shim; every example is a
deterministic function of its drawn seed (virtual clock, no sleeps).
"""

from __future__ import annotations

from tests._hypothesis_compat import given, settings
from tests.strategies import make_arrival_schedule, make_corpus, make_queries, seeds

from repro.index import DocumentStore, build_indexes
from repro.runtime.clock import ManualClock
from repro.search.frontend import SearchRequest, ServingFrontend
from repro.search.service import ServiceDaemon

MAX_BATCH = 4


def _build_index(seed):
    spec = make_corpus(seed, max_docs=8)
    store = DocumentStore.from_texts(spec.texts)
    index = build_indexes(
        store,
        sw_count=spec.sw_count,
        fu_count=spec.fu_count,
        max_distance=spec.max_distance,
    )
    queries = make_queries(seed, spec, n_queries=4)
    return index, queries


def _frontend(index, **kw):
    kw.setdefault("max_batch", MAX_BATCH)
    kw.setdefault("clock", ManualClock())
    return ServingFrontend(index, **kw)


def _daemon(index, **kw):
    clock = ManualClock()
    fe = _frontend(index, clock=clock)
    kw.setdefault("max_queue", 64)
    return ServiceDaemon(fe, clock=clock, **kw)


def _replay(daemon, spec):
    schedule = [
        (t, SearchRequest(query=q, top_k=k, deadline_sec=d))
        for t, q, k, d in spec.events
    ]
    return daemon.replay(schedule, service_time_sec=spec.service_time_sec)


def _batches(tickets):
    """Reconstruct launched batches: non-shed tickets in seq order, taken
    in runs of their recorded batch_size (FIFO pops consecutive seqs)."""
    served = sorted((t for t in tickets if not t.shed_at_queue), key=lambda t: t.seq)
    out, i = [], 0
    while i < len(served):
        size = served[i].batch_size
        assert size >= 1
        out.append(served[i : i + size])
        i += size
    return out


def _doc_key(resp):
    return [
        (d.doc_id, d.score, [(f.doc_id, f.start, f.end) for f in d.fragments])
        for d in resp.docs
    ]


@given(seeds)
@settings(max_examples=5, deadline=None, derandomize=True)
def test_daemon_is_byte_identical_to_serial_reference(seed):
    """Single replica: for ANY arrival schedule, replaying through the
    daemon yields responses identical to a serial search_many run over the
    reconstructed slates with the recorded effective deadlines."""
    index, queries = _build_index(seed)
    spec = make_arrival_schedule(seed, queries, max_events=14)
    daemon = _daemon(index)
    tickets = _replay(daemon, spec)
    assert all(t.done() for t in tickets)

    reference = _frontend(index)  # fresh caches, same config
    for batch in _batches(tickets):
        expected = reference.search_many(
            [
                SearchRequest(
                    query=t.request.query,
                    top_k=t.request.top_k,
                    deadline_sec=t.effective_deadline_sec,
                )
                for t in batch
            ]
        )
        for t, want in zip(batch, expected):
            got = t.result(timeout=0)
            assert _doc_key(got) == _doc_key(want), (t.request.query, t.seq)
            assert got.stats.partial == want.stats.partial
            assert got.stats.results == want.stats.results


@given(seeds)
@settings(max_examples=5, deadline=None, derandomize=True)
def test_admission_order_no_starvation_and_conservation(seed):
    """FIFO batches over consecutive seqs, every ticket completed, and the
    exact submitted == completed + shed_queue conservation law."""
    index, queries = _build_index(seed)
    spec = make_arrival_schedule(seed, queries, max_events=18)
    daemon = _daemon(index, max_queue=6)  # small queue: sheds can occur
    tickets = _replay(daemon, spec)

    assert all(t.done() for t in tickets), "a ticket was starved"
    seq_cursor = -1
    for batch in _batches(tickets):
        batch_seqs = [t.seq for t in batch]
        # consecutive among served tickets and globally ascending: FIFO
        assert batch_seqs == sorted(batch_seqs)
        assert batch_seqs[0] > seq_cursor
        seq_cursor = batch_seqs[-1]
        assert len(batch) <= daemon.batch_limit
        assert all(t.replica == batch[0].replica for t in batch)

    m = daemon.metrics()
    assert m["submitted"] == len(tickets)
    assert m["submitted"] == m["completed"] + m["shed_queue"]
    assert m["queued"] == 0 and m["inflight_requests"] == 0
    assert m["batched_requests"] == m["completed"]


@given(seeds)
@settings(max_examples=5, deadline=None, derandomize=True)
def test_sheds_and_partials_are_flagged_and_never_cached(seed):
    """Every response that is not the complete exact result carries a flag
    (shed / partial), queue-sheds are empty, and no flagged response is
    ever served back out of the result cache."""
    index, queries = _build_index(seed)
    spec = make_arrival_schedule(seed, queries, max_events=18)
    daemon = _daemon(index, max_queue=3)
    tickets = _replay(daemon, spec)

    for t in tickets:
        resp = t.result(timeout=0)
        if t.shed_at_queue:
            assert resp.stats.shed == 1 and resp.stats.partial
            assert resp.docs == [] and resp.stats.cache_hits == 0
        if resp.stats.partial:
            # flagged responses must never have come from the cache
            assert resp.stats.cache_hits == 0

    # never cached: re-serving a query that was shed (or partial) under no
    # pressure yields the frontend's full exact result, not a cached stub
    flagged = [
        t for t in tickets if t.result(timeout=0).stats.partial
    ]
    if flagged:
        q = flagged[0].request.query
        top_k = flagged[0].request.top_k
        again = daemon.submit(SearchRequest(query=q, top_k=top_k))
        daemon.drain()
        resp = again.result(timeout=0)
        want = _frontend(index).search(q, top_k=top_k)
        assert resp.stats.shed == 0 and not resp.stats.partial
        assert _doc_key(resp) == _doc_key(want)


def test_saturating_burst_batches_continuously():
    """Deterministic saturation: arrivals every 1 ms against a 10 ms
    virtual service time MUST form multi-request batches from arrivals
    admitted while earlier batches were in flight — mean occupancy > 1 is
    the §16.2 continuous-batching evidence (exact, not statistical)."""
    index, queries = _build_index(7)
    daemon = _daemon(index)
    schedule = [
        (i * 0.001, SearchRequest(query=queries[i % len(queries)], top_k=10))
        for i in range(12)
    ]
    tickets = daemon.replay(schedule, service_time_sec=0.010)
    assert all(t.done() for t in tickets)
    m = daemon.metrics()
    assert m["mean_batch_occupancy"] > 1.0, m
    assert m["batches"] < len(tickets)
    # and the queue wait the late arrivals paid is an exact virtual-time
    # quantity: ticket 1 arrived at 1 ms and launched when batch 0 retired
    # at 10 ms -> exactly 9 ms of queue wait
    assert tickets[1].queue_wait_sec == 0.010 - 0.001


def test_multi_replica_round_robin_serves_all_exactly():
    """Two replicas over one index: batches alternate replicas, every
    response equals the single-frontend reference exactly, and both
    replicas actually served (the routing property)."""
    index, queries = _build_index(11)
    clock = ManualClock()
    replicas = [
        ServingFrontend(index, max_batch=2, clock=clock) for _ in range(2)
    ]
    daemon = ServiceDaemon(replicas, clock=clock, max_queue=64)
    schedule = [
        (i * 0.0005, SearchRequest(query=queries[i % len(queries)], top_k=10))
        for i in range(10)
    ]
    tickets = daemon.replay(schedule, service_time_sec=0.002)
    reference = ServingFrontend(index, max_batch=2, clock=ManualClock())
    for t in tickets:
        want = reference.search(t.request.query, top_k=10)
        assert _doc_key(t.result(timeout=0)) == _doc_key(want)
    m = daemon.metrics()
    assert all(n > 0 for n in m["per_replica_batches"]), m
