"""Torn-write properties for every durable artifact (DESIGN.md §12.4 + §18.1).

A crash can cut ANY file at ANY byte boundary.  The contract, per artifact:

* ``postings.bin`` / ``manifest.json`` (snapshot store): a truncated
  snapshot NEVER restores silently wrong — the CRC/structure verify fails
  loudly and recovery restores the next-older intact snapshot exactly
  (restore-older-or-fail-loudly).
* ``records.bin`` (§18 WAL): truncation at any boundary yields exactly a
  *prefix* of the acknowledged records — the torn frame and everything
  after it are cut, never reinterpreted — and restore+replay of that
  prefix still succeeds end to end.

Every byte boundary of small artifacts is swept exhaustively; the
restore-level equivalence is additionally property-tested at drawn
boundaries via the ``tests._hypothesis_compat`` shim (real ``hypothesis``
when installed).
"""

from __future__ import annotations

import bisect
import functools
import tempfile
from pathlib import Path

import pytest

from tests._hypothesis_compat import given, settings, st

from repro.index import IncrementalIndexer, index_sets_equal, synthesize_corpus
from repro.index.store import StoreError
from repro.index.wal import encode_frame, read_frames, replay

SW, FU, D = 10, 20, 5


@functools.lru_cache(maxsize=1)
def _build_lineage():
    """One two-snapshot WAL-attached lineage shared by the sweeps (each
    test restores the exact original bytes after mutating).  Built once
    per process in a mkdtemp (not a pytest fixture: the hypothesis-shim
    ``@given`` cannot mix drawn arguments with fixtures)."""
    root = Path(tempfile.mkdtemp(prefix="torn_writes_"))
    store = synthesize_corpus(n_docs=4, doc_len=12, vocab_size=40, seed=3)
    docs = list(store.documents)
    ix = IncrementalIndexer(
        sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=store.lemmatizer
    )
    ix.enable_wal(root)
    ix.add_prelemmatized(docs[:3])
    ix.commit()
    ix.snapshot(root)  # snap_0
    ix.add_prelemmatized(docs[3:])
    ix.commit()
    ix.snapshot(root)  # snap_1
    ix.delete_document(docs[0].doc_id)  # post-snapshot WAL tail
    ix.commit()
    return root, store, ix


@pytest.fixture()
def lineage():
    return _build_lineage()


def _state(ix):
    return (
        sorted(ix.documents),
        sorted(ix.tombstones),
        ix.index.to_index_set(),
    )


def _same_state(a, b):
    if a[0] != b[0] or a[1] != b[1]:
        return False, "doc/tombstone sets differ"
    return index_sets_equal(a[2], b[2])


def _sweep_restores_older_or_fails_loudly(lineage, victim_rel):
    root, store, live = lineage
    victim = root / victim_rel
    original = victim.read_bytes()
    want_latest = _state(live)
    older = IncrementalIndexer.restore(root, snapshot_id=0, lemmatizer=store.lemmatizer)
    want_older = _state(older)
    try:
        for cut in range(len(original)):
            victim.write_bytes(original[:cut])
            try:
                got = IncrementalIndexer.restore(root, lemmatizer=store.lemmatizer)
            except Exception:
                pass  # loud failure: any raise is acceptable, silence is not
            else:
                # restored despite the damage: the state MUST still be the
                # exact latest state (i.e. the damage was provably immaterial)
                eq, why = _same_state(_state(got), want_latest)
                assert eq, (
                    f"{victim_rel} cut at {cut}: restore returned WRONG data "
                    f"instead of failing loudly: {why}"
                )
            # the untouched older snapshot always restores exactly
            if cut % 293 == 0:
                fallback = IncrementalIndexer.restore(
                    root, snapshot_id=0, lemmatizer=store.lemmatizer
                )
                eq, why = _same_state(_state(fallback), want_older)
                assert eq, f"older-snapshot fallback diverged at cut {cut}: {why}"
    finally:
        victim.write_bytes(original)


def test_postings_truncated_at_every_boundary(lineage):
    root, _, _ = lineage
    seg = sorted((root / "snap_1").glob("seg_*"))[-1]
    _sweep_restores_older_or_fails_loudly(
        lineage, seg.relative_to(root) / "postings.bin"
    )


def test_manifest_truncated_at_every_boundary(lineage):
    _sweep_restores_older_or_fails_loudly(lineage, "snap_1/manifest.json")


def test_segment_manifest_truncated_at_every_boundary(lineage):
    root, _, _ = lineage
    seg = sorted((root / "snap_1").glob("seg_*"))[-1]
    _sweep_restores_older_or_fails_loudly(
        lineage, seg.relative_to(root) / "manifest.json"
    )


# ---------------------------------------------------------------------------
# the same sweep reused for §18.1 WAL frames
# ---------------------------------------------------------------------------


def test_wal_records_truncated_at_every_boundary_is_acked_prefix(tmp_path):
    """Pure frame-level property, exhaustively at EVERY byte boundary: a
    cut anywhere yields exactly the longest prefix of complete valid
    frames — never a reinterpretation, never a resync past the tear."""
    payloads = [
        ("add", {"docs": [{"doc_id": i, "text": f"t{i}", "lemmas": []}]})
        for i in range(3)
    ] + [("commit", {"fl": None}), ("delete", {"doc_id": 1})]
    frames = [encode_frame(i, t, p) for i, (t, p) in enumerate(payloads)]
    blob = b"".join(frames)
    ends = []
    off = 0
    for f in frames:
        off += len(f)
        ends.append(off)
    path = tmp_path / "records.bin"
    for cut in range(len(blob) + 1):
        path.write_bytes(blob[:cut])
        got = read_frames(path)
        want = bisect.bisect_right(ends, cut)  # complete frames fully inside
        assert [r.seq for r in got] == list(range(want)), f"cut at {cut}"
        # physical truncation back to the last complete frame
        assert path.read_bytes() == blob[: ends[want - 1] if want else 0]


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10**9))
def test_wal_tail_truncation_restores_acked_prefix_end_to_end(raw_cut):
    """Restore-level property at drawn boundaries: cut the ACTIVE WAL tail
    anywhere, and a fresh restore succeeds, replaying exactly the
    surviving acked prefix — equal to snapshot + replay of those same
    records (zero phantoms, zero silent loss beyond the torn frame)."""
    root, store, _ = _build_lineage()
    tail = sorted(root.glob("wal/wal_*"))[-1] / "records.bin"
    original = tail.read_bytes()
    full_records = read_frames(tail, truncate=False)
    cut = raw_cut % (len(original) + 1)
    try:
        tail.write_bytes(original[:cut])
        got = IncrementalIndexer.restore(root, lemmatizer=store.lemmatizer)
        surviving = read_frames(tail, truncate=False)
        # the survivors are exactly a prefix of the acked tail records
        assert [r.seq for r in surviving] == [
            r.seq for r in full_records[: len(surviving)]
        ]
        # expected: snapshot-only restore + replay of that same prefix
        # (every tail record follows the sealing checkpoint anchor)
        expect = IncrementalIndexer.restore(
            root, lemmatizer=store.lemmatizer, replay_wal=False
        )
        replay(expect, surviving)
        eq, why = _same_state(_state(got), _state(expect))
        assert eq, f"cut at {cut}: {why}"
    finally:
        tail.write_bytes(original)
