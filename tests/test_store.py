"""Durable index store round-trip tests (DESIGN.md §12).

Pins the §12 exactness contract end to end: snapshots restore
byte-identically (``index_sets_equal`` plus per-slice dtype/value equality
of the lazy decodes), survive tombstones / multi-segment histories /
FL-drift re-keying / buffered docs, reject corrupted or truncated stores
loudly, retain atomically with keep-N GC, and resume generation tokens
under a bumped restore epoch (§12.5).  Warm-started sharded services and
frontends serve fragment sets identical to their pre-restart selves.
"""

from __future__ import annotations

import json
import shutil

import numpy as np
import pytest

from repro.index import (
    IncrementalIndexer,
    PAPER_EXAMPLE_DOCS,
    StoreError,
    StoredIndexSet,
    index_sets_equal,
    latest_snapshot,
    synthesize_corpus,
)
from repro.index.store import FAMILY_WIDTH
from repro.search.engine import SearchEngine


def _small_indexer(n_docs=24, batches=3, seed=11, sw=30, fu=60):
    store = synthesize_corpus(n_docs=n_docs, doc_len=60, vocab_size=500, seed=seed)
    texts = [d.text for d in store.documents]
    ix = IncrementalIndexer(sw_count=sw, fu_count=fu, max_distance=5,
                            lemmatizer=store.lemmatizer)
    step = max(1, len(texts) // batches)
    for i in range(0, len(texts), step):
        ix.add_documents(texts[i : i + step])
        ix.commit()
    return ix, store


def _assert_round_trip(ix, tmp_path, lemmatizer=None):
    ix.snapshot(tmp_path)
    rx = IncrementalIndexer.restore(tmp_path, lemmatizer=lemmatizer)
    eq, why = index_sets_equal(rx.index.to_index_set(), ix.index.to_index_set())
    assert eq, why
    return rx


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------


def test_empty_indexer_round_trip(tmp_path):
    ix = IncrementalIndexer(sw_count=10, fu_count=10, max_distance=5)
    rx = _assert_round_trip(ix, tmp_path)
    assert rx.segments == []
    assert rx.fl is None
    resp = SearchEngine(rx).search("who are you", top_k=5)
    assert resp.docs == []


def test_single_and_multi_segment_round_trip(tmp_path):
    ix, store = _small_indexer()
    assert len(ix.segments) > 1
    rx = _assert_round_trip(ix, tmp_path, lemmatizer=store.lemmatizer)
    # engine fragments identical on both sides
    for query in ("who are you who", "to be or not to be"):
        a = SearchEngine(ix, lemmatizer=store.lemmatizer).search(query, top_k=16)
        b = SearchEngine(rx, lemmatizer=store.lemmatizer).search(query, top_k=16)
        fa = sorted((d.doc_id, f.start, f.end) for d in a.docs for f in d.fragments)
        fb = sorted((d.doc_id, f.start, f.end) for d in b.docs for f in d.fragments)
        assert fa == fb, query


def test_tombstones_round_trip_and_compact_after_restore(tmp_path):
    ix, store = _small_indexer()
    victims = sorted(ix.documents)[::5]
    for v in victims:
        ix.delete_document(v)
    ix.commit()  # FL refresh over the survivors (rebuild oracle's FL basis)
    rx = _assert_round_trip(ix, tmp_path, lemmatizer=store.lemmatizer)
    assert rx.tombstones == ix.tombstones
    rx.compact()
    assert not rx.tombstones
    eq, why = index_sets_equal(rx.index.to_index_set(), rx.rebuild_index_set())
    assert eq, f"post-restore compact != rebuild: {why}"


def test_fl_drift_history_round_trip(tmp_path):
    """Commits with refresh_fl drift the FL-list across generations
    (superseded docs, NSW remaps); the snapshot must capture the drifted
    state exactly and keep drifting after restore."""
    ix, store = _small_indexer(n_docs=30, batches=5)
    report = ix.commit()  # extra refresh generation
    rx = _assert_round_trip(ix, tmp_path, lemmatizer=store.lemmatizer)
    assert any(seg.superseded for seg in ix.segments) == any(
        seg.superseded for seg in rx.segments
    )
    # keep mutating both sides in lockstep: results must stay identical
    extra = ["the who are an english rock band", "time and time again and again"]
    ix.add_documents(extra)
    rx.add_documents(extra)
    ix.commit()
    rx.commit()
    eq, why = index_sets_equal(rx.index.to_index_set(), ix.index.to_index_set())
    assert eq, f"post-restore drift commit diverged: {why}"


def test_buffered_documents_survive_snapshot(tmp_path):
    ix, store = _small_indexer()
    ix.add_documents(["an uncommitted buffered document about war"])
    rx = _assert_round_trip(ix, tmp_path, lemmatizer=store.lemmatizer)
    assert len(rx._buffer) == 1
    ix.commit()
    rx.commit()
    eq, why = index_sets_equal(rx.index.to_index_set(), ix.index.to_index_set())
    assert eq, f"buffered docs lost: {why}"


def test_lazy_decodes_are_byte_identical(tmp_path):
    ix, _ = _small_indexer()
    ix.snapshot(tmp_path)
    rx = IncrementalIndexer.restore(tmp_path)
    for seg_mem, seg_disk in zip(ix.segments, rx.segments):
        assert isinstance(seg_disk.index, StoredIndexSet)
        for fname in FAMILY_WIDTH:
            mem = getattr(seg_mem.index, fname)
            disk = getattr(seg_disk.index, fname)
            assert set(mem.keys()) == set(disk.keys()), fname
            for key in mem:
                a, b = mem[key], disk[key]
                assert a.dtype == b.dtype and a.shape == b.shape, (fname, key)
                assert np.array_equal(a, b), (fname, key)
        assert set(seg_mem.index.nsw.keys()) == set(seg_disk.index.nsw.keys())
        for lemma, rec in seg_mem.index.nsw.items():
            drec = seg_disk.index.nsw[lemma]
            for f in ("offsets", "stop_lemma", "distance"):
                a, b = getattr(rec, f), getattr(drec, f)
                assert a.dtype == b.dtype and np.array_equal(a, b), (lemma, f)
        # size accounting identical without decoding
        assert seg_disk.index.size_bytes() == seg_mem.index.size_bytes()


# ---------------------------------------------------------------------------
# corruption rejection
# ---------------------------------------------------------------------------


def test_missing_snapshot_rejected(tmp_path):
    with pytest.raises(StoreError):
        IncrementalIndexer.restore(tmp_path)


def test_truncated_blob_rejected(tmp_path):
    ix, _ = _small_indexer(n_docs=10, batches=1)
    snap = ix.snapshot(tmp_path)
    blob = next(snap.glob("seg_*/postings.bin"))
    blob.write_bytes(blob.read_bytes()[:-8])
    with pytest.raises(StoreError, match="truncated"):
        IncrementalIndexer.restore(tmp_path)


def test_bitflip_rejected_by_crc(tmp_path):
    ix, _ = _small_indexer(n_docs=10, batches=1)
    snap = ix.snapshot(tmp_path)
    blob = next(snap.glob("seg_*/postings.bin"))
    raw = bytearray(blob.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    blob.write_bytes(bytes(raw))
    with pytest.raises(StoreError, match="CRC"):
        IncrementalIndexer.restore(tmp_path)
    # verify=False skips the CRC scan (documented fast path): no error here
    IncrementalIndexer.restore(tmp_path, verify=False)


def test_corrupt_manifest_rejected(tmp_path):
    ix, _ = _small_indexer(n_docs=10, batches=1)
    snap = ix.snapshot(tmp_path)
    (snap / "manifest.json").write_text("{not json")
    with pytest.raises(StoreError, match="corrupt manifest"):
        IncrementalIndexer.restore(tmp_path)


def test_unknown_format_version_rejected(tmp_path):
    ix, _ = _small_indexer(n_docs=10, batches=1)
    snap = ix.snapshot(tmp_path)
    m = json.loads((snap / "manifest.json").read_text())
    m["format_version"] = 999
    (snap / "manifest.json").write_text(json.dumps(m))
    with pytest.raises(StoreError, match="format version"):
        IncrementalIndexer.restore(tmp_path)


def test_fl_signature_mismatch_rejected(tmp_path):
    """A segment keyed under a different FL generation (here: spliced in
    from a different snapshot) must be refused — §10.2 row generation is
    FL-relative, so serving it would be silently wrong."""
    ix, store = _small_indexer(n_docs=12, batches=1, seed=1)
    other, _ = _small_indexer(n_docs=12, batches=1, seed=2)
    snap = ix.snapshot(tmp_path / "a")
    other_snap = other.snapshot(tmp_path / "b")
    seg = next(snap.glob("seg_*"))
    other_seg = next(other_snap.glob("seg_*"))
    shutil.rmtree(seg)
    shutil.copytree(other_seg, seg)
    with pytest.raises(StoreError, match="FL signature"):
        IncrementalIndexer.restore(tmp_path / "a")


# ---------------------------------------------------------------------------
# retention + generation tokens across restarts (§12.5)
# ---------------------------------------------------------------------------


def test_snapshot_retention_keeps_newest(tmp_path):
    ix, _ = _small_indexer(n_docs=8, batches=1)
    for _ in range(3):
        ix.snapshot(tmp_path, keep=2)
    names = sorted(p.name for p in tmp_path.glob("snap_*"))
    assert names == ["snap_1", "snap_2"]
    assert latest_snapshot(tmp_path) == 2
    # explicit snapshot_id picks the older retained snapshot
    rx = IncrementalIndexer.restore(tmp_path, snapshot_id=1)
    eq, why = index_sets_equal(rx.index.to_index_set(), ix.index.to_index_set())
    assert eq, why


def test_generation_token_resumes_under_new_epoch(tmp_path):
    ix, _ = _small_indexer(n_docs=8, batches=2)
    token_live = ix.generation_token
    ix.snapshot(tmp_path)
    rx = IncrementalIndexer.restore(tmp_path)
    # same index state, but a different boot: tokens must not collide with
    # anything the previous process could have produced after the snapshot
    assert rx.generation_token == (1, token_live)
    assert rx.generation_token != token_live
    rx.add_documents(["one more doc"])
    rx.commit()
    assert rx.generation_token == (1, token_live + 1)
    # a second restart bumps the epoch again
    rx.snapshot(tmp_path)
    rx2 = IncrementalIndexer.restore(tmp_path)
    assert rx2.generation_token == (2, token_live + 1)
    # SIBLING restores of one snapshot (crash loop) claim distinct epochs
    # via the persisted lineage counter: two boots that then diverge can
    # never mint the same token for different states (§12.5)
    boot_a = IncrementalIndexer.restore(tmp_path)
    boot_b = IncrementalIndexer.restore(tmp_path)
    assert boot_a.generation_token != boot_b.generation_token
    boot_a.add_documents(["boot a text"])
    boot_a.commit()
    boot_b.add_documents(["entirely different boot b words"])
    boot_b.commit()
    assert boot_a.generation_token != boot_b.generation_token


# ---------------------------------------------------------------------------
# warm-started serving layers
# ---------------------------------------------------------------------------


def _frags(resp):
    return sorted((d.doc_id, f.start, f.end) for d in resp.docs for f in d.fragments)


def test_sharded_service_snapshot_restore(tmp_path):
    from repro.index import DocumentStore
    from repro.search.distributed import ShardedSearchService

    store = DocumentStore.from_texts(
        list(PAPER_EXAMPLE_DOCS) + ["to be or not to be", "i need you now"]
    )
    svc = ShardedSearchService(store, n_shards=2, sw_count=20, fu_count=10,
                               incremental=True)
    svc.snapshot(tmp_path)
    restored = ShardedSearchService.restore(tmp_path)
    assert restored.n_shards == svc.n_shards
    for query in ("who are you who", "to be or not to be"):
        assert _frags(restored.search(query, top_k=16)) == _frags(
            svc.search(query, top_k=16)
        ), query
    # tokens resume under per-shard restore epochs: never equal pre-restart
    assert restored.generation_token != svc.generation_token
    # mutation endpoints still work after restore
    restored.add_documents(["a brand new document"])
    restored.commit()


def test_service_manifest_pins_survive_torn_snapshot(tmp_path):
    """A snapshot run that crashes after writing shard snapshots but before
    publishing service.json must leave the previous consistent set fully
    restorable — retention only runs after the manifest publish, so pinned
    ids are never collected (DESIGN.md §12.4)."""
    from repro.index import DocumentStore
    from repro.search.distributed import ShardedSearchService

    store = DocumentStore.from_texts(list(PAPER_EXAMPLE_DOCS))
    svc = ShardedSearchService(store, n_shards=2, sw_count=10, fu_count=5,
                               incremental=True)
    svc.snapshot(tmp_path, keep=1)
    want = _frags(svc.search("who are you", top_k=16))
    # simulate two crashed snapshot runs: shards advance, manifest never does
    svc.add_documents(["new doc one"])
    svc.commit()
    for _ in range(2):
        for i, ix in enumerate(svc.indexers):
            ix.snapshot(tmp_path / f"shard_{i:02d}", keep=0)
    restored = ShardedSearchService.restore(tmp_path)  # the OLD pinned set
    assert _frags(restored.search("who are you", top_k=16)) == want
    # a completed snapshot re-pins and GCs down to keep=1 per shard
    svc.snapshot(tmp_path, keep=1)
    assert all(
        len(list((tmp_path / f"shard_{i:02d}").glob("snap_*"))) == 1
        for i in range(2)
    )
    restored = ShardedSearchService.restore(tmp_path)
    assert _frags(restored.search("who are you", top_k=16)) == _frags(
        svc.search("who are you", top_k=16)
    )


def test_frontend_warm_start_from_snapshot(tmp_path):
    from repro.search.frontend import ServingFrontend

    ix, store = _small_indexer(n_docs=12, batches=2)
    cold = ServingFrontend(ix)
    queries = ["who are you who", "to be or not to be"]
    before = [cold.search(q, top_k=8) for q in queries]
    ix.snapshot(tmp_path)
    warm = ServingFrontend.from_snapshot(tmp_path)
    after = [warm.search(q, top_k=8) for q in queries]
    for b, a in zip(before, after):
        assert _frags(b) == _frags(a)
    # and a sharded snapshot is auto-detected via service.json
    from repro.index import DocumentStore
    from repro.search.distributed import ShardedSearchService

    svc_store = DocumentStore.from_texts(list(PAPER_EXAMPLE_DOCS))
    svc = ShardedSearchService(svc_store, n_shards=2, sw_count=10, fu_count=5,
                               incremental=True)
    svc.snapshot(tmp_path / "svc")
    warm_svc = ServingFrontend.from_snapshot(tmp_path / "svc")
    assert _frags(warm_svc.search("who are you", top_k=8)) == _frags(
        ServingFrontend(svc).search("who are you", top_k=8)
    )


def test_crash_mid_commit_recovers_under_fresh_epoch(tmp_path):
    """A shard killed mid-``commit`` leaves a torn generation (siblings
    committed, the victim not).  The next batch's §14 probe barrier must
    recover the victim from its snapshot under a DISTINCT §12.5 epoch, so
    no token minted before the crash can ever alias the recovered state —
    and a second crash/recovery claims yet another epoch (DESIGN.md §14)."""
    from repro.index import DocumentStore
    from repro.runtime.fault_tolerance import RestartPolicy
    from repro.search.distributed import ShardedSearchService
    from repro.search.resilience import (
        FaultEvent,
        ResiliencePolicy,
        ShardCrash,
    )

    store = DocumentStore.from_texts(
        list(PAPER_EXAMPLE_DOCS) + ["to be or not to be"]
    )
    svc = ShardedSearchService(store, n_shards=2, sw_count=10, fu_count=5,
                               incremental=True)
    svc.snapshot(tmp_path)
    svc.enable_resilience(policy=ResiliencePolicy(
        restart=RestartPolicy(max_restarts=1, min_backoff_s=0.0),
        breaker_cooldown_s=0.0,
    ))
    seen_tokens = {svc.generation_token}
    svc.injector.schedule = (
        FaultEvent("shard.commit", "kill", shard=1, at_call=0),
    )
    svc.add_documents(["freshly added words", "more new words after that"])
    with pytest.raises(ShardCrash):
        svc.commit()
    # torn state: shard 0 committed the new generation, shard 1 is down
    assert svc.injector.is_down(1)
    seen_tokens.add(svc.generation_token)

    resp = svc.search("who are you", top_k=16)
    st = resp.stats
    assert st.recoveries == 1 and st.shards_degraded == 0 and not st.partial
    assert not svc.injector.is_down(1)
    # the recovered shard resumed from the snapshot under a fresh epoch:
    # its token is an (epoch, mutations) tuple no pre-crash token equals
    epoch_1 = svc.indexers[1]._restore_epoch
    assert epoch_1 >= 1
    assert isinstance(svc.indexers[1].generation_token, tuple)
    assert svc.generation_token not in seen_tokens
    seen_tokens.add(svc.generation_token)

    # a second crash + recovery of the SAME lineage claims a HIGHER epoch
    # (the persisted §12.5 counter): sibling boots can never mint colliding
    # tokens even when their mutation counters realign
    svc.injector.schedule = (
        FaultEvent("shard.commit", "kill", shard=1, at_call=1),
    )
    svc.add_documents(["another doc for the second torn commit"])
    with pytest.raises(ShardCrash):
        svc.commit()
    resp = svc.search("who are you", top_k=16)
    assert resp.stats.recoveries == 1
    assert svc.indexers[1]._restore_epoch > epoch_1
    assert svc.generation_token not in seen_tokens
    # after recovery the commit path works again end to end
    svc.injector.schedule = ()
    svc.add_documents(["a final committed document"])
    svc.commit()
    assert svc.generation_token not in seen_tokens
