"""Unit tests for the incremental segmented indexing subsystem."""

import numpy as np
import pytest

from repro.core.lemma import LemmaType
from repro.index import (
    DocumentStore,
    IncrementalIndexer,
    build_indexes,
    index_sets_equal,
    synthesize_corpus,
)
from repro.index.incremental import merge_posting_arrays
from repro.search.distributed import ShardedSearchService
from repro.search.engine import SearchEngine

SW, FU, D = 40, 80, 5


def _texts(n=24, seed=11):
    store = synthesize_corpus(n_docs=n, doc_len=50, vocab_size=250, seed=seed)
    return [d.text for d in store.documents], store.lemmatizer


def _assert_equal_rebuild(ix, ctx=""):
    equal, why = index_sets_equal(ix.index.to_index_set(), ix.rebuild_index_set())
    assert equal, f"{ctx}: {why}"


def test_single_commit_equals_full_build():
    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)
    ix.add_documents(texts)
    report = ix.commit()
    assert report["new_docs"] == len(texts) and report["segments"] == 1
    _assert_equal_rebuild(ix, "single commit")


def test_multi_batch_commits_with_fl_drift():
    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)
    drifted = 0
    for i in range(0, len(texts), 6):
        ix.add_documents(texts[i : i + 6])
        drifted += ix.commit()["rekeyed_docs"]
    assert len(ix.segments) == len(range(0, len(texts), 6))
    assert drifted > 0  # Zipf growth must move lemmas across classes
    _assert_equal_rebuild(ix, "multi batch")


def test_delete_is_immediately_visible_then_exact_after_commit():
    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)
    ids = ix.add_documents(texts)
    ix.commit()
    victim = ids[3]
    before = {int(r[0]) for a in (ix.index.ordinary[l] for l in ix.index.ordinary) for r in a}
    assert victim in before
    ix.delete_document(victim)
    # tombstone filter: no posting of any index references the victim
    view = ix.index
    for mapping in (view.ordinary, view.pair, view.triple, view.stop_single, view.stop_pair):
        for key in mapping:
            rows = mapping[key]
            assert victim not in set(rows[:, 0].tolist())
    ix.commit()  # FL refresh over the survivors
    _assert_equal_rebuild(ix, "after delete")


def test_delete_unknown_raises_and_buffered_delete_unbuffers():
    texts, lem = _texts(n=4)
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, lemmatizer=lem)
    with pytest.raises(KeyError):
        ix.delete_document(99)
    ids = ix.add_documents(texts)
    ix.delete_document(ids[0])  # still buffered: dropped, never indexed
    ix.commit()
    assert ids[0] not in ix.documents and ids[0] not in ix.tombstones
    _assert_equal_rebuild(ix, "buffered delete")


def test_compact_budget_bounds_segments_and_collects_tombstones():
    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)
    for i in range(0, len(texts), 4):
        ix.add_documents(texts[i : i + 4])
        ix.commit()
    n_before = len(ix.segments)
    ids = sorted(ix.documents)
    for victim in ids[::5]:
        ix.delete_document(victim)
    total = sum(seg.live_bytes() for seg in ix.segments)
    report = ix.compact(memory_budget_bytes=total // 2 + 1)
    assert 1 < report["segments"] < n_before  # budget forced multiple outputs
    assert report["collected"] == len(ids[::5])
    assert not ix.tombstones
    ix.commit()
    _assert_equal_rebuild(ix, "budgeted compact")
    ix.compact()
    assert len(ix.segments) == 1
    _assert_equal_rebuild(ix, "full compact")


def test_pinned_fl_mode_matches_rebuild_with_same_fl():
    """commit(refresh_fl=False): serving mode — no drift scan; exact w.r.t. a
    rebuild that pins the same FL-list."""
    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)
    ix.add_documents(texts[:12])
    ix.commit()  # generation 1 establishes the FL-list
    pinned = ix.fl
    ix.add_documents(texts[12:])
    report = ix.commit(refresh_fl=False)
    assert report["rekeyed_docs"] == 0 and ix.fl is pinned
    rebuild = build_indexes(
        ix.surviving_store(), sw_count=SW, fu_count=FU, max_distance=D, fl=pinned
    )
    equal, why = index_sets_equal(ix.index.to_index_set(), rebuild)
    assert equal, why


def test_fl_drift_rekeys_only_affected_docs():
    """A new batch that flips one lemma's class re-keys only documents whose
    own lemma signature changed — not the whole corpus."""
    lem = None
    base = ["alpha beta gamma"] * 3 + ["delta epsilon zeta"] * 3
    ix = IncrementalIndexer(sw_count=2, fu_count=2, max_distance=D)
    ix.add_documents(base)
    ix.commit()
    # flood 'zeta': it climbs into the stop class, drifting classes for the
    # second doc group; the alpha/beta/gamma docs keep their relative order
    ix.add_documents(["zeta " * 30])
    report = ix.commit()
    assert 0 < report["rekeyed_docs"] < len(base) + 1
    _assert_equal_rebuild(ix, "class flip")


def test_fl_refresh_skips_docs_with_unchanged_signature():
    """Regression: a lemma merely ENTERING the FL list (unknown under the
    old generation, e.g. a pinned shard-global FL that lagged the corpus)
    must not re-key docs whose lemma order signature is unchanged — the
    sentinel tie-break already ordered those lemmas by string, so their
    rows are byte-identical under both generations."""
    from repro.core.lemma import FLList

    fl0 = FLList.from_frequencies({"the": 100, "walk": 50},
                                  sw_count=1, fu_count=1)
    ix = IncrementalIndexer(sw_count=1, fu_count=1, max_distance=D)
    ix.add_documents(["walk qux zebra"])
    ix.commit(fl=fl0)  # qux/zebra unknown to fl0: sentinel FL-numbers
    # the refreshed FL now knows qux/zebra — same relative order, same types
    fl1 = FLList.from_frequencies(
        {"the": 100, "walk": 50, "qux": 2, "zebra": 1}, sw_count=1, fu_count=1
    )
    report = ix.commit(fl=fl1)
    assert report["rekeyed_docs"] == 0, "signature-invariant doc was re-keyed"
    rebuild = build_indexes(
        ix.surviving_store(), sw_count=1, fu_count=1, max_distance=D, fl=fl1
    )
    equal, why = index_sets_equal(ix.index.to_index_set(), rebuild)
    assert equal, why


def test_fl_refresh_rekeys_exactly_signature_changed_docs():
    """The re-key set equals {committed docs whose lemma_order_signature
    changed between generations} — no over- or under-approximation."""
    from repro.core.keys import lemma_order_signature
    from repro.core.lemma import FLList

    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D,
                            lemmatizer=lem)
    ix.add_documents(texts[:16])
    ix.commit()
    old_fl = ix.fl
    ix.add_documents(texts[16:] + ["zeta " * 40])  # force drift
    new_fl = FLList.from_frequencies(
        ix.surviving_frequencies(), sw_count=SW, fu_count=FU
    )
    expected = sum(
        lemma_order_signature(ix._doc_lemmas[doc_id], old_fl)
        != lemma_order_signature(ix._doc_lemmas[doc_id], new_fl)
        for doc_id in ix.documents
    )
    report = ix.commit(fl=new_fl)
    assert report["rekeyed_docs"] == expected
    assert 0 < expected < len(ix.documents)  # a real partial re-key
    _assert_equal_rebuild(ix, "exact re-key set")


def test_segmented_view_serves_all_key_arities(small_corpus):
    texts = [d.text for d in small_corpus.documents]
    ix = IncrementalIndexer(
        sw_count=60, fu_count=150, max_distance=5, lemmatizer=small_corpus.lemmatizer
    )
    for i in range(0, len(texts), 17):
        ix.add_documents(texts[i : i + 17])
        ix.commit()
    full = build_indexes(small_corpus, sw_count=60, fu_count=150, max_distance=5)
    view = ix.index
    for key in list(full.triple)[:40]:
        assert np.array_equal(view.key_postings(key), full.key_postings(key))
    for key in list(full.stop_pair)[:40]:
        assert np.array_equal(view.key_postings(key), full.key_postings(key))
    for key in list(full.stop_single)[:40]:
        assert np.array_equal(view.key_postings(key), full.key_postings(key))


def test_engine_picks_up_commits_live():
    ix = IncrementalIndexer(sw_count=10, fu_count=5, max_distance=5)
    engine = SearchEngine(ix, algorithm="se2.4")
    assert engine.search("who are you").docs == []
    ix.add_documents(["who are you is the album by the who"])
    assert engine.search("who are you").docs == []  # buffered, not committed
    ix.commit()
    assert engine.search("who are you").docs  # same engine object, new docs
    ix.delete_document(0)
    assert engine.search("who are you").docs == []  # tombstone visible


def test_materialized_snapshot_survives_fl_drift():
    """to_index_set() snapshots may share arrays with segments (single-
    contributor merges return originals); a later drift commit must not
    rewrite the snapshot's NSW stop ids under its pinned FL generation."""
    texts, lem = _texts()
    ix = IncrementalIndexer(sw_count=SW, fu_count=FU, max_distance=D, lemmatizer=lem)
    ix.add_documents(texts[:10])
    ix.commit()
    snap = ix.index.to_index_set()
    payload = {l: r.stop_lemma.copy() for l, r in snap.nsw.items()}
    rebuild_old = build_indexes(
        ix.surviving_store(), sw_count=SW, fu_count=FU, max_distance=D
    )
    ix.add_documents(texts[10:])
    assert ix.commit()["drifted_lemmas"] > 0  # the drift must actually occur
    for l, before in payload.items():
        assert np.array_equal(snap.nsw[l].stop_lemma, before), l
    equal, why = index_sets_equal(snap, rebuild_old)
    assert equal, why


def test_merge_posting_arrays_order():
    a = np.array([[0, 3], [2, 1]], dtype=np.int32)
    b = np.array([[1, 0], [1, 9], [3, 2]], dtype=np.int32)
    merged = merge_posting_arrays([a, b], width=2)
    assert merged.tolist() == [[0, 3], [1, 0], [1, 9], [2, 1], [3, 2]]


def test_sharded_incremental_service_matches_static(small_corpus):
    texts = [d.text for d in small_corpus.documents]
    svc = ShardedSearchService(
        DocumentStore.from_texts(texts[:40], lemmatizer=small_corpus.lemmatizer),
        n_shards=3,
        sw_count=60,
        fu_count=150,
        algorithm="fused",
        incremental=True,
    )
    svc.add_documents(texts[40:])
    svc.delete_document(5)
    svc.commit()
    svc.compact(memory_budget_bytes=100_000)
    survivors = [i for i in range(len(texts)) if i != 5]
    ref_store = DocumentStore.from_texts(texts, lemmatizer=small_corpus.lemmatizer).subset(
        survivors
    )
    ref = ShardedSearchService(
        ref_store, n_shards=3, sw_count=60, fu_count=150, algorithm="fused"
    )
    for query in ["who are you who", "to be or not to be", "one at a time"]:
        got = svc.search(query, top_k=8)
        want = ref.search(query, top_k=8)
        f_got = sorted((d.doc_id, f.start, f.end) for d in got.docs for f in d.fragments)
        f_want = sorted((d.doc_id, f.start, f.end) for d in want.docs for f in d.fragments)
        assert f_got == f_want, query
    with pytest.raises(RuntimeError):
        ref.add_documents(["nope"])
